"""Greedy list-scheduler simulator (the gem5 stand-in, §4)."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import (EDag, grid_report, latency_sweep, make_cache,
                        memory_cost_bounds, non_memory_cost, simulate,
                        simulate_batch, simulate_reference, sweep_grid,
                        sweep_report, total_cost_bounds)


def test_chain_exact():
    g = EDag()
    prev = None
    for _ in range(5):
        v = g.add_vertex(is_mem=True)
        if prev is not None:
            g.add_edge(prev, v)
        prev = v
    assert simulate(g, m=4, alpha=100.0) == pytest.approx(500.0)


def test_parallel_limited_by_slots():
    g = EDag()
    for _ in range(8):
        g.add_vertex(is_mem=True)
    # 8 accesses, 2 slots -> 4 rounds
    assert simulate(g, m=2, alpha=100.0) == pytest.approx(400.0)
    assert simulate(g, m=8, alpha=100.0) == pytest.approx(100.0)


def test_compute_unbounded():
    g = EDag()
    for _ in range(100):
        g.add_vertex(is_mem=False)
    assert simulate(g, m=1, alpha=100.0) == pytest.approx(1.0)


def test_mixed_pipeline():
    """mem -> compute -> mem chain: alpha + 1 + alpha."""
    g = EDag()
    a = g.add_vertex(is_mem=True)
    b = g.add_vertex(is_mem=False)
    c = g.add_vertex(is_mem=True)
    g.add_edge(a, b)
    g.add_edge(b, c)
    assert simulate(g, m=4, alpha=50.0) == pytest.approx(101.0)


def test_latency_sweep_monotone():
    g = EDag()
    prev = None
    for i in range(20):
        v = g.add_vertex(is_mem=(i % 2 == 0))
        if prev is not None:
            g.add_edge(prev, v)
        prev = v
    times = latency_sweep(g, alphas=[50, 100, 200], m=4)
    assert times[0] < times[1] < times[2]


@given(st.integers(1, 30), st.integers(1, 6), st.floats(1.0, 100.0))
def test_width_vs_slots(width, m, alpha):
    g = EDag()
    for _ in range(width):
        g.add_vertex(is_mem=True)
    t = simulate(g, m=m, alpha=alpha)
    assert t == pytest.approx(np.ceil(width / m) * alpha)


# ---------------------------------------------------- batched engine oracle

@st.composite
def sim_cases(draw):
    """Random topological DAG + machine model + tie-heavy alpha grid."""
    n = draw(st.integers(1, 60))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    g = EDag()
    for i in range(n):
        g.add_vertex(is_mem=bool(rng.random() < 0.5), nbytes=8.0)
        for j in range(i):
            if rng.random() < 0.12:
                g.add_edge(j, i)
    m = draw(st.integers(1, 5))
    cs = draw(st.integers(0, 5))
    # small integers make event-time ties plentiful — the adversarial case
    # for the (R, E, vid) issue-order verification
    alphas = rng.choice([0.5, 1.0, 2.0, 3.0, 50.0, 200.0, 333.25],
                        size=5, replace=False)
    return g, m, cs, alphas


@given(sim_cases())
def test_batched_matches_reference_exactly(case):
    """simulate_batch is bit-identical to the retained heapq engine."""
    g, m, cs, alphas = case
    got = simulate_batch(g, alphas, m=m, compute_slots=cs)
    want = np.array([simulate_reference(g, m=m, alpha=float(a),
                                        compute_slots=cs) for a in alphas])
    assert np.array_equal(got, want)


@given(sim_cases())
def test_batched_within_eq2_bounds(case):
    """Every batched makespan obeys the Eq-2 upper bound of its alpha
    point and the Eq-1 memory lower bound (the Eq-2 *lower* bound adds
    all of C serially, which a parallel machine may beat)."""
    g, m, _cs, alphas = case
    lay = g.mem_layers()
    C = non_memory_cost(g)
    times = simulate_batch(g, alphas, m=m)   # unbounded ALU: Eq-2 regime
    for a, t in zip(alphas, times):
        _, hi = total_cost_bounds(lay.W, lay.D, m, float(a), C)
        mem_lo, _ = memory_cost_bounds(lay.W, lay.D, m, float(a))
        assert mem_lo - 1e-6 <= t <= hi + 1e-6


def test_batched_traced_kernels_cached_and_uncached():
    """Traced PolyBench kernels, with and without a cache model, sweep to
    bit-identical makespans on both engines."""
    from repro.apps import polybench

    alphas = np.arange(50.0, 301.0, 50.0)
    for name in ("gemm", "trisolv", "trmm"):
        for cache_size in (0, 1024):
            g = polybench.trace_kernel(name, 6,
                                       cache=make_cache(cache_size))
            got = simulate_batch(g, alphas, m=4, compute_slots=8)
            want = np.array([simulate_reference(g, m=4, alpha=float(a),
                                                compute_slots=8)
                             for a in alphas])
            assert np.array_equal(got, want), (name, cache_size)


def test_latency_sweep_batch_flag_equivalent():
    g = EDag()
    prev = None
    for i in range(40):
        v = g.add_vertex(is_mem=(i % 3 == 0))
        if prev is not None and i % 5:
            g.add_edge(prev, v)
        prev = v
    alphas = [50.0, 75.0, 100.0, 250.0]
    assert np.array_equal(latency_sweep(g, alphas, m=2, compute_slots=3),
                          latency_sweep(g, alphas, m=2, compute_slots=3,
                                        batch=False))


def test_batched_degenerate_machine_models():
    """Non-positive / non-finite parameters keep reference semantics."""
    g = EDag()
    a = g.add_vertex(is_mem=True)
    b = g.add_vertex(is_mem=False)
    g.add_edge(a, b)
    for alphas in ([0.0, 50.0], [-1.0, 2.0]):
        got = simulate_batch(g, alphas, m=2)
        want = [simulate_reference(g, m=2, alpha=float(x)) for x in alphas]
        assert np.array_equal(got, np.array(want))


def test_sweep_report_simulated_is_batched_reference():
    from repro.apps import polybench

    g = polybench.trace_kernel("mvt", 6)
    alphas = [50.0, 150.0, 300.0]
    rep = sweep_report(g, alphas, simulate_points=True, compute_slots=4)
    want = np.array([simulate_reference(g, alpha=a, compute_slots=4)
                     for a in alphas])
    assert np.array_equal(rep["simulated"], want)


# ----------------------------------------------- alpha × m × slots grids

@st.composite
def grid_cases(draw):
    """Random topological DAG + small alpha × m × compute_slots grid with
    tie-heavy alphas (the adversarial case for schedule reuse across
    machine configurations)."""
    n = draw(st.integers(1, 40))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    g = EDag()
    for i in range(n):
        g.add_vertex(is_mem=bool(rng.random() < 0.5), nbytes=8.0)
        for j in range(i):
            if rng.random() < 0.12:
                g.add_edge(j, i)
    ms = sorted({draw(st.integers(1, 5)), draw(st.integers(1, 5))})
    css = sorted({draw(st.integers(0, 4)), draw(st.integers(0, 4))})
    alphas = rng.choice([0.5, 1.0, 2.0, 3.0, 50.0, 200.0, 333.25],
                        size=3, replace=False)
    return g, ms, css, alphas


@given(grid_cases())
def test_sweep_grid_matches_reference_exactly(case):
    """Every grid point is bit-identical to the per-point heapq oracle."""
    g, ms, css, alphas = case
    grid = sweep_grid(g, alphas, ms=ms, compute_slots=css)
    assert grid.shape == (len(alphas), len(ms), len(css))
    for i, a in enumerate(alphas):
        for j, m in enumerate(ms):
            for l, cs in enumerate(css):
                want = simulate_reference(g, m=m, alpha=float(a),
                                          compute_slots=cs)
                assert grid[i, j, l] == want, (a, m, cs)


@given(grid_cases())
def test_sweep_grid_matches_stacked_singles(case):
    """The grid equals the stack of per-(m, cs) single-axis sweeps."""
    g, ms, css, alphas = case
    grid = sweep_grid(g, alphas, ms=ms, compute_slots=css)
    singles = np.stack(
        [np.stack([latency_sweep(g, alphas, m=m, compute_slots=cs)
                   for cs in css], axis=-1) for m in ms], axis=1)
    assert np.array_equal(grid, singles)


def test_sweep_grid_memory_budget_invariant():
    """Streaming the replay in tiny memory-budget chunks cannot change a
    single bit of the grid."""
    rng = np.random.default_rng(7)
    g = EDag()
    for i in range(50):
        g.add_vertex(is_mem=bool(rng.random() < 0.6))
        for j in range(i):
            if rng.random() < 0.1:
                g.add_edge(j, i)
    alphas = np.linspace(40.0, 300.0, 14)
    full = sweep_grid(g, alphas, ms=[1, 4], compute_slots=[0, 3])
    tiny = sweep_grid(g, alphas, ms=[1, 4], compute_slots=[0, 3],
                      mem_budget=1)     # forces single-point chunks
    assert np.array_equal(full, tiny)


def test_sweep_grid_degenerate_and_empty():
    g = EDag()
    assert sweep_grid(g, [50.0], ms=[2], compute_slots=[0]).shape == \
        (1, 1, 1)
    a = g.add_vertex(is_mem=True)
    b = g.add_vertex(is_mem=False)
    g.add_edge(a, b)
    grid = sweep_grid(g, [0.0, 50.0], ms=[1, 2], compute_slots=[0])
    for i, al in enumerate([0.0, 50.0]):
        for j, m in enumerate([1, 2]):
            assert grid[i, j, 0] == simulate_reference(g, m=m, alpha=al)


def test_axis_latency_grid_matches_sweep_per_m():
    """The (axis, m, alpha) fabric grid reduces to axis_latency_sweep at
    the m each AxisSensitivity was built with, and recomputes Eq-3
    lambda per m elsewhere."""
    from repro.core import (AxisSensitivity, axis_latency_grid,
                            axis_latency_sweep, lambda_abs)

    m0 = 4
    per_axis = {
        "model": AxisSensitivity(axis="model", W=64, D=8, bytes=2.0 ** 30,
                                 lam=lambda_abs(64, 8, m0),
                                 lam_seconds=lambda_abs(64, 8, m0) * 1e-6),
        "pod": AxisSensitivity(axis="pod", W=16, D=4, bytes=2.0 ** 28,
                               lam=lambda_abs(16, 4, m0),
                               lam_seconds=lambda_abs(16, 4, m0) * 1e-5),
    }
    alphas = [1e-6, 5e-6, 10e-6]
    ms = [2, m0, 8]
    step = 1e-3
    grid = axis_latency_grid(per_axis, alphas, ms=ms, step_seconds=step)
    sweep = axis_latency_sweep(per_axis, alphas, step_seconds=step)
    for axis in per_axis:
        g = grid[axis]
        assert g["lam"].shape == (len(ms),)
        assert g["lam_seconds"].shape == g["Lam"].shape == \
            (len(ms), len(alphas))
        # the m0 row is exactly the single-axis sweep
        j = ms.index(m0)
        assert np.array_equal(g["lam_seconds"][j], sweep[axis]["lam_seconds"])
        assert np.array_equal(g["Lam"][j], sweep[axis]["Lam"])
        # other rows recompute Eq 3 from (W, D, m)
        W, D = per_axis[axis].W, per_axis[axis].D
        for jj, m in enumerate(ms):
            assert g["lam"][jj] == lambda_abs(W, D, m)
    assert axis_latency_grid({}, alphas, ms=ms, step_seconds=step) == {}


def test_grid_report_matches_scalar_metrics():
    """grid_report's stacked Eq 3-4 / Eq 1-2 values equal the scalar
    helpers at every (alpha, m) point, and its simulated grid equals
    sweep_grid."""
    from repro.core import lambda_abs

    rng = np.random.default_rng(11)
    g = EDag()
    for i in range(45):
        g.add_vertex(is_mem=bool(rng.random() < 0.5))
        for j in range(i):
            if rng.random() < 0.12:
                g.add_edge(j, i)
    alphas = [50.0, 125.0, 300.0]
    ms = [1, 2, 4]
    css = [0, 2]
    rep = grid_report(g, alphas, ms=ms, compute_slots=css,
                      simulate_points=True)
    lay = g.mem_layers()
    C = non_memory_cost(g)
    for j, m in enumerate(ms):
        assert rep["lam"][j] == lambda_abs(lay.W, lay.D, m)
        for i, a in enumerate(alphas):
            lo, hi = total_cost_bounds(lay.W, lay.D, m, a, C)
            assert rep["t_lower"][i, j] == lo
            assert rep["t_upper"][i, j] == hi
    sr = sweep_report(g, alphas)       # m=4 default of CostModelParams
    assert np.array_equal(rep["t_inf"], sr["t_inf"])
    assert np.array_equal(rep["Lam"][:, ms.index(4)], sr["Lam"])
    assert np.array_equal(rep["simulated"],
                          sweep_grid(g, alphas, ms=ms, compute_slots=css))


# ------------------------------------------- unsorted / duplicate alphas

def _tie_graph(seed: int = 17, n: int = 50) -> EDag:
    rng = np.random.default_rng(seed)
    g = EDag()
    for i in range(n):
        g.add_vertex(is_mem=bool(rng.random() < 0.5))
        for j in range(i):
            if rng.random() < 0.1:
                g.add_edge(j, i)
    return g


def test_latency_sweep_unsorted_duplicate_alphas():
    """Regression: unsorted / duplicate alphas used to be swept verbatim
    (wasted replay columns, arbitrary recording master).  They are now
    deduped and sorted internally, and results come back in caller order
    — bit-identical to the per-point reference."""
    g = _tie_graph()
    alphas = [200.0, 0.5, 50.0, 200.0, 3.0, 0.5, 50.0]
    want = np.array([simulate_reference(g, m=3, alpha=a, compute_slots=2)
                     for a in alphas])
    got = latency_sweep(g, alphas, m=3, compute_slots=2)
    assert np.array_equal(got, want)
    assert np.array_equal(simulate_batch(g, alphas, m=3, compute_slots=2),
                          want)
    # duplicates collapse in the replay: a sweep of repeated benign
    # points still records exactly once (tie-heavy alphas above may
    # legitimately re-record on order shifts, so count on a clean grid)
    from repro.core import schedule_cache as sc
    sc.reset_stats()
    latency_sweep(g, [200.0, 50.0, 200.0, 50.0, 125.0], m=3,
                  compute_slots=2, use_cache=False)
    assert sc.stats["record_runs"] == 1


def test_sweep_grid_unsorted_duplicate_alphas():
    g = _tie_graph(seed=19)
    alphas = [300.0, 50.0, 50.0, 2.0]
    grid = sweep_grid(g, alphas, ms=[1, 4], compute_slots=[0, 2])
    for i, a in enumerate(alphas):
        for j, m in enumerate([1, 4]):
            for l, cs in enumerate([0, 2]):
                assert grid[i, j, l] == simulate_reference(
                    g, m=m, alpha=a, compute_slots=cs)


# --------------------------------------------- per-vertex latency classes

@st.composite
def class_cases(draw):
    """Random tie-heavy DAG + random class overlay + (P, C) alpha-row
    grid — the adversarial case for the slot-provenance verification
    (small-integer alphas make pop-order ties plentiful)."""
    n = draw(st.integers(1, 60))
    rng = np.random.default_rng(draw(st.integers(0, 2 ** 31)))
    g = EDag()
    for i in range(n):
        g.add_vertex(is_mem=bool(rng.random() < 0.5), nbytes=8.0)
        for j in range(i):
            if rng.random() < 0.12:
                g.add_edge(j, i)
    C = draw(st.integers(1, 3))
    g._finalize()
    g.set_mem_classes(rng.integers(0, C, size=g.n_vertices,
                                   dtype=np.int32))
    m = draw(st.integers(1, 4))
    cs = draw(st.integers(0, 3))
    P = draw(st.integers(1, 5))
    palette = np.array([0.5, 1.0, 2.0, 3.0, 50.0, 200.0, 333.25])
    alphas = rng.choice(palette, size=(P, C))
    return g, m, cs, alphas


@given(class_cases())
def test_class_batch_matches_reference_exactly(case):
    """Class-vector simulate_batch is bit-identical to the per-event
    class reference loop at every alpha row."""
    from repro.core import simulate_reference_classes

    g, m, cs, alphas = case
    got = simulate_batch(g, alphas, m=m, compute_slots=cs)
    want = np.array([simulate_reference_classes(g, row, m=m,
                                                compute_slots=cs)
                     for row in alphas])
    assert np.array_equal(got, want)


@given(class_cases())
def test_class_collapse_differential(case):
    """THE collapse property: when every class shares one alpha, the
    class-vector path is bit-identical to the scalar path — engine,
    reference loop, and per-point scalar reference all agree."""
    from repro.core import simulate_reference_classes

    g, m, cs, alphas = case
    flat = np.repeat(alphas[:, :1], alphas.shape[1], axis=1)
    got = simulate_batch(g, flat, m=m, compute_slots=cs)
    scalar = simulate_batch(g, flat[:, 0], m=m, compute_slots=cs)
    assert np.array_equal(got, scalar)
    for row, want in zip(flat, scalar):
        assert simulate_reference_classes(g, row, m=m,
                                          compute_slots=cs) == want
        assert simulate_reference(g, m=m, alpha=float(row[0]),
                                  compute_slots=cs) == want


@given(class_cases())
def test_class_sweep_grid_and_latency_sweep(case):
    """2-D grids thread through sweep_grid / latency_sweep unchanged:
    every (row, m, cs) point equals the per-event class reference, and
    the batch=False path agrees bitwise."""
    from repro.core import simulate_reference_classes

    g, m, cs, alphas = case
    ms, css = sorted({1, m}), sorted({0, cs})
    grid = sweep_grid(g, alphas, ms=ms, compute_slots=css)
    assert grid.shape == (len(alphas), len(ms), len(css))
    for i, row in enumerate(alphas):
        for j, mm in enumerate(ms):
            for l, ccs in enumerate(css):
                assert grid[i, j, l] == simulate_reference_classes(
                    g, row, m=mm, compute_slots=ccs), (i, mm, ccs)
    assert np.array_equal(
        latency_sweep(g, alphas, m=m, compute_slots=cs),
        latency_sweep(g, alphas, m=m, compute_slots=cs, batch=False))


def test_class_degenerate_rows_keep_reference_semantics():
    """Rows containing non-positive or non-finite alphas route through
    the per-event class loop, like the scalar degenerate screen."""
    from repro.core import simulate_reference_classes

    g = EDag()
    a = g.add_vertex(is_mem=True)
    b = g.add_vertex(is_mem=True)
    c = g.add_vertex(is_mem=False)
    g.add_edge(a, c)
    g._finalize()
    g.set_mem_classes(np.array([0, 1, 0], dtype=np.int32))
    alphas = np.array([[0.0, 50.0], [50.0, -1.0], [2.0, 3.0]])
    got = simulate_batch(g, alphas, m=2)
    want = np.array([simulate_reference_classes(g, row, m=2)
                     for row in alphas])
    assert np.array_equal(got, want)


def test_class_overlay_changes_makespan_and_digest():
    """A non-uniform overlay actually prices classes differently, and
    the class digest keys plan memoization correctly (overlay change =>
    digest change; clearing restores the scalar digest)."""
    g = _tie_graph(seed=23)
    g._finalize()
    assert g.mem_class_digest() == "scalar"
    cls = (np.arange(g.n_vertices) % 2).astype(np.int32)
    g.set_mem_classes(cls)
    d1 = g.mem_class_digest()
    assert d1 != "scalar"
    fast_slow = simulate_batch(g, np.array([[1.0, 500.0]]), m=2)[0]
    slow_fast = simulate_batch(g, np.array([[500.0, 1.0]]), m=2)[0]
    uniform = simulate_batch(g, np.array([500.0]), m=2)[0]
    assert fast_slow < uniform and slow_fast < uniform
    g.set_mem_classes(None)
    assert g.mem_class_digest() == "scalar"
    assert simulate_batch(g, np.array([500.0]), m=2)[0] == uniform


def test_class_column_validation():
    g = _tie_graph(seed=29)
    g._finalize()
    with pytest.raises(ValueError):
        g.set_mem_classes(np.zeros(3, dtype=np.int32))   # wrong length
    with pytest.raises(ValueError):
        g.set_mem_classes(-np.ones(g.n_vertices, dtype=np.int32))
    g.set_mem_classes(np.full(g.n_vertices, 2, dtype=np.int32))
    with pytest.raises(ValueError):
        g.mem_class_column(2)          # class id 2 needs >= 3 classes
    assert g.mem_class_column(3).max() == 2


def test_class_grid_report_brackets_and_prices_exactly():
    """grid_report on 2-D class rows: simulated/t_inf price each vertex
    by its own class exactly, while the Eq 1-2 bounds from each row's
    extreme alphas bracket the simulated makespan."""
    from repro.core import simulate_reference_classes, t_inf_sweep

    g = _tie_graph(seed=31)
    g._finalize()
    g.set_mem_classes((np.arange(g.n_vertices) % 2).astype(np.int32))
    rows = np.array([[30.0, 400.0], [400.0, 30.0], [120.0, 120.0]])
    ms, css = [2, 4], [0]
    rep = grid_report(g, rows, ms=ms, compute_slots=css,
                      simulate_points=True)
    assert rep["simulated"].shape == (len(rows), len(ms), len(css))
    for i, row in enumerate(rows):
        for j, m in enumerate(ms):
            sim = rep["simulated"][i, j, 0]
            assert sim == simulate_reference_classes(g, row, m=m)
            assert rep["t_lower"][i, j] <= sim <= rep["t_upper"][i, j]
    assert np.array_equal(rep["t_inf"], t_inf_sweep(g, rows))
    # the uniform row collapses: bounds equal the scalar report's
    flat = grid_report(g, np.array([120.0]), ms=ms)
    assert np.array_equal(rep["t_lower"][2], flat["t_lower"][0])
    assert np.array_equal(rep["t_upper"][2], flat["t_upper"][0])
    assert np.array_equal(rep["Lam"][2], flat["Lam"][0])
    g.set_mem_classes(None)


# ------------------------------------------------- fig10-13 seed regression

def _force_reference_engine(monkeypatch):
    """Route every latency_sweep through the per-point seed engine."""
    import repro.core.scheduler as sched
    monkeypatch.setattr(sched, "_MIN_BATCH_POINTS", 10 ** 9)


def test_fig10_11_output_matches_seed_engine(monkeypatch):
    from benchmarks import fig10_11_lambda

    got = fig10_11_lambda.run(N=5)
    _force_reference_engine(monkeypatch)
    want = fig10_11_lambda.run(N=5)
    assert got == want


def test_fig12_output_matches_seed_engine(monkeypatch):
    from benchmarks import fig12_Lambda

    got = fig12_Lambda.run(N=5)
    _force_reference_engine(monkeypatch)
    want = fig12_Lambda.run(N=5)
    assert got == want


def test_fig13_register_pressure_variants():
    from benchmarks import fig13_depth

    res = fig13_depth.run(sizes=(6, 10))
    # idealized trmm keeps constant depth; a 3-register file spills the
    # accumulator every iteration and reproduces trmm_spill's linear
    # depth growth exactly, while 8 registers fit the loop body (§5.1)
    assert res["trmm"][0] == res["trmm"][1]
    assert res["trmm@regs8"] == res["trmm"]
    assert res["trmm@regs3"] == res["trmm_spill"]
    assert res["trmm_spill"][1] > res["trmm_spill"][0]
