"""Greedy list-scheduler simulator (the gem5 stand-in, §4)."""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import EDag, latency_sweep, simulate


def test_chain_exact():
    g = EDag()
    prev = None
    for _ in range(5):
        v = g.add_vertex(is_mem=True)
        if prev is not None:
            g.add_edge(prev, v)
        prev = v
    assert simulate(g, m=4, alpha=100.0) == pytest.approx(500.0)


def test_parallel_limited_by_slots():
    g = EDag()
    for _ in range(8):
        g.add_vertex(is_mem=True)
    # 8 accesses, 2 slots -> 4 rounds
    assert simulate(g, m=2, alpha=100.0) == pytest.approx(400.0)
    assert simulate(g, m=8, alpha=100.0) == pytest.approx(100.0)


def test_compute_unbounded():
    g = EDag()
    for _ in range(100):
        g.add_vertex(is_mem=False)
    assert simulate(g, m=1, alpha=100.0) == pytest.approx(1.0)


def test_mixed_pipeline():
    """mem -> compute -> mem chain: alpha + 1 + alpha."""
    g = EDag()
    a = g.add_vertex(is_mem=True)
    b = g.add_vertex(is_mem=False)
    c = g.add_vertex(is_mem=True)
    g.add_edge(a, b)
    g.add_edge(b, c)
    assert simulate(g, m=4, alpha=50.0) == pytest.approx(101.0)


def test_latency_sweep_monotone():
    g = EDag()
    prev = None
    for i in range(20):
        v = g.add_vertex(is_mem=(i % 2 == 0))
        if prev is not None:
            g.add_edge(prev, v)
        prev = v
    times = latency_sweep(g, alphas=[50, 100, 200], m=4)
    assert times[0] < times[1] < times[2]


@given(st.integers(1, 30), st.integers(1, 6), st.floats(1.0, 100.0))
def test_width_vs_slots(width, m, alpha):
    g = EDag()
    for _ in range(width):
        g.add_vertex(is_mem=True)
    t = simulate(g, m=m, alpha=alpha)
    assert t == pytest.approx(np.ceil(width / m) * alpha)
