"""jaxpr frontend (core.jaxpr): array-granularity eDAGs of JAX programs.

Pins the eDAG shape (vertex/edge counts, labels, costs, mem classification)
and the trace digest of small jitted functions so the frontend's contract is
load-bearing: any change to equation emission, scan unrolling or the
mem-threshold rule shows up as a concrete diff here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import edag_from_fn, report, simulate_reference_classes
from repro.apps.polybench import JAX_KERNELS


def dot_plus_one():
    return edag_from_fn(lambda a, b: jnp.dot(a, b) + 1.0,
                        jnp.ones((4, 8)), jnp.ones((8, 3)))


def test_dot_plus_one_shape_and_costs():
    """Two equations (dot_general, add), one SSA edge; dot costs 2*M*N*K
    flops, the broadcast add one flop per output element."""
    g = dot_plus_one()
    dg = g.trace_digest()                      # also finalizes the arrays
    assert (g.n_vertices, g.n_edges) == (2, 1)
    assert g.labels() == ["dot_general", "add"]
    assert g.is_mem.sum() == 2                 # threshold 0: every eqn is mem
    assert list(g.cost) == [2.0 * 4 * 3 * 8, 4 * 3 * 1.0]
    # dot touches (4*8 + 8*3 + 4*3) f32 elements; add reads+writes 4*3 + out
    assert list(g.nbytes) == [(32 + 24 + 12) * 4.0, (12 + 12) * 4.0]
    r = report(g)
    assert (r.W, r.D) == (2, 2)
    assert r.t1 == 192.0 + 12.0 + 196.0        # t1 folds mem stall at alpha0
    assert len(dg) == 64


def test_digest_stable_and_jit_transparent():
    """Same program => same digest, across rebuilds and under jax.jit (the
    pjit call is inlined, not emitted as an opaque vertex)."""
    g = dot_plus_one()
    assert dot_plus_one().trace_digest() == g.trace_digest()
    gj = edag_from_fn(jax.jit(lambda a, b: jnp.dot(a, b) + 1.0),
                      jnp.ones((4, 8)), jnp.ones((8, 3)))
    assert (gj.n_vertices, gj.n_edges) == (2, 1)
    assert gj.labels() == ["dot_general", "add"]
    assert gj.trace_digest() == g.trace_digest()


def test_mem_threshold_reclassifies_and_changes_digest():
    """A huge threshold demotes every vertex to compute; the digest covers
    the mem classification, so it must move."""
    g = dot_plus_one()
    gt = edag_from_fn(lambda a, b: jnp.dot(a, b) + 1.0,
                      jnp.ones((4, 8)), jnp.ones((8, 3)),
                      mem_threshold_bytes=1e9)
    dt = gt.trace_digest()
    assert gt.is_mem.sum() == 0
    assert (gt.n_vertices, gt.n_edges) == (2, 1)
    assert dt != g.trace_digest()


def test_scan_unrolls_with_carry_depth():
    """scan of length 10 with a (mul, add) body unrolls to a 20-vertex
    carry chain — sequential-over-time structure becomes depth."""
    def body(c, x):
        c = c * 0.5 + x
        return c, c

    f = lambda xs: jax.lax.scan(body, jnp.float32(0.0), xs)
    g = edag_from_fn(f, jnp.ones(10, jnp.float32))
    g.trace_digest()
    assert (g.n_vertices, g.n_edges) == (20, 19)
    assert g.labels()[:2] == ["mul", "add"]
    assert report(g).D == 20                   # pure chain: D == W

    g4 = edag_from_fn(f, jnp.ones(10, jnp.float32), scan_unroll_limit=4)
    g4.trace_digest()
    assert (g4.n_vertices, g4.n_edges) == (8, 7)
    assert report(g4).D == 8


def test_scan_stacked_ys_wired_to_final_producers():
    """Regression: stacked ys used to be attributed to the first *carry*
    vertex instead of the final iteration's actual producer.  Two carries
    (add / sub chains) plus a non-carry ys eqn (mul): the downstream
    consumer of ys must depend on the last mul, not a carry vertex."""
    def body(carry, x):
        c1, c2 = carry
        y = x * 3.0
        return (c1 + x, c2 - x), y

    def f(xs):
        (c1, _), ys = jax.lax.scan(
            body, (jnp.float32(0.0), jnp.float32(1.0)), xs)
        return jnp.sum(ys) + c1

    g = edag_from_fn(f, jnp.ones(3, jnp.float32))
    g.trace_digest()
    labels = g.labels()
    # 3 steps x (mul, add, sub) + reduce_sum + final add
    assert labels == ["mul", "add", "sub"] * 3 + ["reduce_sum", "add"]
    rid = labels.index("reduce_sum")
    ys_preds = {int(s) for s, d in zip(g.src, g.dst) if d == rid}
    assert {labels[p] for p in ys_preds} == {"mul"}
    assert ys_preds == {6}                     # the *last* step's mul
    # the carry output still rides the carry chain into the final add
    fin_preds = {int(s) for s, d in zip(g.src, g.dst) if d == rid + 1}
    assert {labels[p] for p in fin_preds} == {"reduce_sum", "add"}


def test_cond_keeps_max_cost_branch():
    """Regression: ``cond`` used to traverse only ``branches[0]`` (the
    false branch), silently dropping the other branch's cost and depth.
    The frontend now emits the max-cost branch — worst-case-path
    semantics — so the dot_general side must survive regardless of
    which slot it lands in."""
    def f(v):
        return jax.lax.cond(jnp.sum(v) > 0.0,
                            lambda x: jnp.sum(x @ x.T),   # expensive: true
                            lambda x: jnp.sum(x),          # cheap: false
                            v)

    g = edag_from_fn(f, jnp.ones((8, 8)))
    g.trace_digest()
    assert "dot_general" in g.labels()
    # pinned two-branch shape: pred (reduce_sum, gt, convert) + expensive
    # branch body (transpose, dot_general, reduce_sum)
    assert g.labels() == ["reduce_sum", "gt", "convert_element_type",
                          "transpose", "dot_general", "reduce_sum"]
    # orientation swap: expensive branch as branches[0] keeps working
    gs = edag_from_fn(
        lambda v: jax.lax.cond(jnp.sum(v) > 0.0, lambda x: jnp.sum(x),
                               lambda x: jnp.sum(x @ x.T), v),
        jnp.ones((8, 8)))
    gs.trace_digest()
    assert "dot_general" in gs.labels()


def test_dot_general_batched_flops_pinned():
    """Batched matmul cost must be the hand-computed 2*B*M*N*K."""
    B, M, N, K = 2, 4, 3, 8
    g = edag_from_fn(lambda a, b: jnp.einsum("bmk,bkn->bmn", a, b),
                     jnp.ones((B, M, K)), jnp.ones((B, K, N)))
    g.trace_digest()
    assert g.labels() == ["dot_general"]
    assert list(g.cost) == [2.0 * B * M * N * K]


def test_dot_general_flops_survives_lhs_misindex():
    """Regression: ``_eqn_flops`` indexed only the lhs shape with the lhs
    contracting dims, so a dims tuple whose lhs indices don't fit the lhs
    rank raised IndexError.  The contraction extent is the same K on both
    operands, so the rhs contracting sizes are a valid fallback."""
    from types import SimpleNamespace as NS
    from repro.core.jaxpr import _eqn_flops
    B, M, N, K = 2, 4, 3, 8
    aval = lambda shape: NS(shape=shape)
    eqn = NS(primitive=NS(name="dot_general"),
             params={"dimension_numbers": (((5,), (1,)), ((0,), (0,)))},
             invars=[NS(aval=aval((B, M, K))), NS(aval=aval((B, K, N)))],
             outvars=[NS(aval=aval((B, M, N)))])
    assert _eqn_flops(eqn) == 2.0 * B * M * N * K


def test_checkpoint_body_inlined_not_opaque():
    """``jax.checkpoint`` lowers to the ``remat2`` primitive; the frontend
    must inline its body like any other call, not emit one opaque vertex
    (whole-model traces collapse otherwise)."""
    f = jax.checkpoint(lambda x: jnp.sum(x * 2.0 + 1.0))
    g = edag_from_fn(lambda x: f(x) * 3.0, jnp.ones(16, jnp.float32))
    g.trace_digest()
    assert "remat2" not in g.labels()
    assert g.labels() == ["mul", "add", "reduce_sum", "mul"]


def test_polybench_jax_gemm_pinned():
    N = 6
    ones = jnp.ones((N, N))
    g = edag_from_fn(JAX_KERNELS["gemm"], ones, ones, ones)
    dg = g.trace_digest()
    assert (g.n_vertices, g.n_edges) == (4, 3)
    assert g.labels() == ["mul", "dot_general", "mul", "add"]
    assert g.is_mem.sum() == 4
    r = report(g)
    assert (r.W, r.D) == (4, 3)                # the two muls are parallel
    assert edag_from_fn(JAX_KERNELS["gemm"], ones, ones,
                        ones).trace_digest() == dg


def test_polybench_jax_atax_pinned():
    g = edag_from_fn(JAX_KERNELS["atax"], jnp.ones((4, 6)), jnp.ones(6))
    g.trace_digest()
    assert (g.n_vertices, g.n_edges) == (3, 2)
    assert g.labels() == ["transpose", "dot_general", "dot_general"]


def test_jaxpr_edag_feeds_class_vector_replay():
    """Frontier-to-backend smoke: a jaxpr-built eDAG accepts a class
    overlay and replays through the class-vector engine; the collapsed
    class vector is bit-identical to the scalar path."""
    g = edag_from_fn(JAX_KERNELS["gemm"], jnp.ones((4, 4)),
                     jnp.ones((4, 4)), jnp.ones((4, 4)))
    g.trace_digest()
    cls = (np.arange(g.n_vertices) % 2).astype(np.int32)
    g.set_mem_classes(cls)
    two = simulate_reference_classes(g, np.array([3.0, 50.0]), m=2)
    flat = simulate_reference_classes(g, np.array([50.0, 50.0]), m=2)
    g.set_mem_classes(None)
    from repro.core import simulate_reference
    assert flat == simulate_reference(g, m=2, alpha=50.0)
    assert two < flat                          # half the verts got faster
