"""jaxpr frontend (core.jaxpr): array-granularity eDAGs of JAX programs.

Pins the eDAG shape (vertex/edge counts, labels, costs, mem classification)
and the trace digest of small jitted functions so the frontend's contract is
load-bearing: any change to equation emission, scan unrolling or the
mem-threshold rule shows up as a concrete diff here.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import edag_from_fn, report, simulate_reference_classes
from repro.apps.polybench import JAX_KERNELS


def dot_plus_one():
    return edag_from_fn(lambda a, b: jnp.dot(a, b) + 1.0,
                        jnp.ones((4, 8)), jnp.ones((8, 3)))


def test_dot_plus_one_shape_and_costs():
    """Two equations (dot_general, add), one SSA edge; dot costs 2*M*N*K
    flops, the broadcast add one flop per output element."""
    g = dot_plus_one()
    dg = g.trace_digest()                      # also finalizes the arrays
    assert (g.n_vertices, g.n_edges) == (2, 1)
    assert g.labels() == ["dot_general", "add"]
    assert g.is_mem.sum() == 2                 # threshold 0: every eqn is mem
    assert list(g.cost) == [2.0 * 4 * 3 * 8, 4 * 3 * 1.0]
    # dot touches (4*8 + 8*3 + 4*3) f32 elements; add reads+writes 4*3 + out
    assert list(g.nbytes) == [(32 + 24 + 12) * 4.0, (12 + 12) * 4.0]
    r = report(g)
    assert (r.W, r.D) == (2, 2)
    assert r.t1 == 192.0 + 12.0 + 196.0        # t1 folds mem stall at alpha0
    assert len(dg) == 64


def test_digest_stable_and_jit_transparent():
    """Same program => same digest, across rebuilds and under jax.jit (the
    pjit call is inlined, not emitted as an opaque vertex)."""
    g = dot_plus_one()
    assert dot_plus_one().trace_digest() == g.trace_digest()
    gj = edag_from_fn(jax.jit(lambda a, b: jnp.dot(a, b) + 1.0),
                      jnp.ones((4, 8)), jnp.ones((8, 3)))
    assert (gj.n_vertices, gj.n_edges) == (2, 1)
    assert gj.labels() == ["dot_general", "add"]
    assert gj.trace_digest() == g.trace_digest()


def test_mem_threshold_reclassifies_and_changes_digest():
    """A huge threshold demotes every vertex to compute; the digest covers
    the mem classification, so it must move."""
    g = dot_plus_one()
    gt = edag_from_fn(lambda a, b: jnp.dot(a, b) + 1.0,
                      jnp.ones((4, 8)), jnp.ones((8, 3)),
                      mem_threshold_bytes=1e9)
    dt = gt.trace_digest()
    assert gt.is_mem.sum() == 0
    assert (gt.n_vertices, gt.n_edges) == (2, 1)
    assert dt != g.trace_digest()


def test_scan_unrolls_with_carry_depth():
    """scan of length 10 with a (mul, add) body unrolls to a 20-vertex
    carry chain — sequential-over-time structure becomes depth."""
    def body(c, x):
        c = c * 0.5 + x
        return c, c

    f = lambda xs: jax.lax.scan(body, jnp.float32(0.0), xs)
    g = edag_from_fn(f, jnp.ones(10, jnp.float32))
    g.trace_digest()
    assert (g.n_vertices, g.n_edges) == (20, 19)
    assert g.labels()[:2] == ["mul", "add"]
    assert report(g).D == 20                   # pure chain: D == W

    g4 = edag_from_fn(f, jnp.ones(10, jnp.float32), scan_unroll_limit=4)
    g4.trace_digest()
    assert (g4.n_vertices, g4.n_edges) == (8, 7)
    assert report(g4).D == 8


def test_polybench_jax_gemm_pinned():
    N = 6
    ones = jnp.ones((N, N))
    g = edag_from_fn(JAX_KERNELS["gemm"], ones, ones, ones)
    dg = g.trace_digest()
    assert (g.n_vertices, g.n_edges) == (4, 3)
    assert g.labels() == ["mul", "dot_general", "mul", "add"]
    assert g.is_mem.sum() == 4
    r = report(g)
    assert (r.W, r.D) == (4, 3)                # the two muls are parallel
    assert edag_from_fn(JAX_KERNELS["gemm"], ones, ones,
                        ones).trace_digest() == dg


def test_polybench_jax_atax_pinned():
    g = edag_from_fn(JAX_KERNELS["atax"], jnp.ones((4, 6)), jnp.ones(6))
    g.trace_digest()
    assert (g.n_vertices, g.n_edges) == (3, 2)
    assert g.labels() == ["transpose", "dot_general", "dot_general"]


def test_jaxpr_edag_feeds_class_vector_replay():
    """Frontier-to-backend smoke: a jaxpr-built eDAG accepts a class
    overlay and replays through the class-vector engine; the collapsed
    class vector is bit-identical to the scalar path."""
    g = edag_from_fn(JAX_KERNELS["gemm"], jnp.ones((4, 4)),
                     jnp.ones((4, 4)), jnp.ones((4, 4)))
    g.trace_digest()
    cls = (np.arange(g.n_vertices) % 2).astype(np.int32)
    g.set_mem_classes(cls)
    two = simulate_reference_classes(g, np.array([3.0, 50.0]), m=2)
    flat = simulate_reference_classes(g, np.array([50.0, 50.0]), m=2)
    g.set_mem_classes(None)
    from repro.core import simulate_reference
    assert flat == simulate_reference(g, m=2, alpha=50.0)
    assert two < flat                          # half the verts got faster
