"""Multi-trace union eDAG suites: the property/differential test layer.

The union engine's contract is blockwise bit-exactness: every per-trace
slice of a suite result must equal the single-trace engine (and hence the
retained heapq reference) exactly — across mixed machine grids, empty and
singleton suites, tie-heavy alphas, cache-cold and cache-warm runs, and
both kernel backends.
"""
import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import (EDag, EDagSuite, concat_edags, grid_report,
                        latency_sweep, simulate_reference, suite_grid_report,
                        suite_latency_sweep, suite_sweep_grid,
                        suite_t_inf_sweep, sweep_grid, t_inf_sweep,
                        schedule_cache as sc)

# kernel backends the differential layer must agree under
try:
    import jax  # noqa: F401
    BACKENDS = ("numpy", "jax")
except Exception:  # pragma: no cover - jax ships in the CI image
    BACKENDS = ("numpy",)


def rand_edag(seed: int, n: int, p_edge: float = 0.12,
              p_mem: float = 0.5) -> EDag:
    rng = np.random.default_rng(seed)
    g = EDag()
    for i in range(n):
        g.add_vertex(is_mem=bool(rng.random() < p_mem), nbytes=8.0)
        for j in range(i):
            if rng.random() < p_edge:
                g.add_edge(j, i)
    g._finalize()
    return g


@pytest.fixture
def cache_env(tmp_path, monkeypatch):
    """Redirect the schedule cache to a private tmp dir, no size floor."""
    monkeypatch.setenv("EDAN_SCHEDULE_CACHE", str(tmp_path))
    monkeypatch.setenv("EDAN_SCHEDULE_CACHE_MIN", "0")
    sc.reset_stats()
    return tmp_path


# ---------------------------------------------------------------- the union

def test_concat_edags_block_diagonal_structure():
    members = [rand_edag(0, 30), rand_edag(1, 0), rand_edag(2, 12)]
    suite = EDagSuite(members, names=["a", "b", "c"])
    u = suite.union
    assert u.n_vertices == sum(g.n_vertices for g in members)
    assert u.n_edges == sum(g.n_edges for g in members)
    assert np.array_equal(suite.offsets, [0, 30, 30, 42])
    assert np.array_equal(suite.trace_id,
                          np.repeat([0, 1, 2], [30, 0, 12]))
    # blockwise payloads survive the concat
    for k, g in enumerate(members):
        off = suite.offsets[k]
        assert np.array_equal(u.is_mem[off:off + g.n_vertices], g.is_mem)
        assert np.array_equal(u.cost[off:off + g.n_vertices], g.cost)
    # no union edge crosses a block boundary
    tid = suite.trace_id
    assert np.array_equal(tid[u.src], tid[u.dst])
    # union analyses decompose blockwise (t1 sums, spans segment)
    assert u.t1() == sum(g.t1() for g in members)
    lvl = u.level
    for k, g in enumerate(members):
        off = suite.offsets[k]
        assert np.array_equal(lvl[off:off + g.n_vertices], g.level)


def test_suite_rejects_bad_inputs():
    with pytest.raises(TypeError):
        EDagSuite([rand_edag(0, 4), "not an edag"])
    with pytest.raises(ValueError):
        EDagSuite([rand_edag(0, 4)], names=["a", "b"])


def test_suite_refuses_mutated_members():
    """EDags are append-only but mutable; a member grown after suite
    construction would silently misalign the frozen segment arrays, so
    every suite operation must refuse loudly instead."""
    g0, g1 = rand_edag(0, 10), rand_edag(1, 8)
    suite = EDagSuite([g0, g1])
    suite.union                               # build the memoized union
    g0.add_vertex(is_mem=True)                # vertex mutation
    for op in (lambda: suite.union,
               lambda: suite.segment_sum(np.zeros(suite.n_vertices)),
               lambda: suite_sweep_grid(suite, [50.0]),
               lambda: suite_t_inf_sweep(suite, [50.0])):
        with pytest.raises(ValueError, match="mutated"):
            op()
    # edge-only mutation (vertex count unchanged) is caught too
    g2, g3 = rand_edag(2, 10), rand_edag(3, 8)
    suite2 = EDagSuite([g2, g3])
    g3.add_edge(0, g3.n_vertices - 1)
    with pytest.raises(ValueError, match="mutated"):
        suite_sweep_grid(suite2, [50.0])


# ------------------------------------------------- property: grid identity

@st.composite
def suite_cases(draw):
    """Random suite (0-3 members, some possibly empty/tiny) + mixed
    machine grid + tie-heavy alphas (the adversarial case for issue-order
    verification across block boundaries)."""
    k = draw(st.integers(0, 3))
    seed = draw(st.integers(0, 2 ** 31))
    rng = np.random.default_rng(seed)
    sizes = [int(rng.integers(0, 45)) for _ in range(k)]
    members = [rand_edag(seed + i, n) for i, n in enumerate(sizes)]
    ms = sorted({draw(st.integers(1, 5)), draw(st.integers(1, 5))})
    css = sorted({draw(st.integers(0, 4)), draw(st.integers(0, 4))})
    alphas = rng.choice([0.5, 1.0, 2.0, 3.0, 50.0, 200.0, 333.25],
                        size=3, replace=False)
    return EDagSuite(members), ms, css, alphas


@given(suite_cases())
def test_suite_grid_bit_identical_to_stacked_singles(case):
    """Every per-trace slice of the union grid equals the single-trace
    engine exactly — the central differential property."""
    suite, ms, css, alphas = case
    grid = suite_sweep_grid(suite, alphas, ms=ms, compute_slots=css)
    assert grid.shape == (suite.n_traces, len(alphas), len(ms), len(css))
    for k, g in enumerate(suite.members):
        want = sweep_grid(g, alphas, ms=ms, compute_slots=css)
        assert np.array_equal(grid[k], want)


@given(suite_cases())
def test_suite_grid_bit_identical_to_reference(case):
    """And hence to the retained per-point heapq oracle."""
    suite, ms, css, alphas = case
    grid = suite_sweep_grid(suite, alphas, ms=ms, compute_slots=css)
    for k, g in enumerate(suite.members):
        for i, a in enumerate(alphas):
            for j, m in enumerate(ms):
                for l, cs in enumerate(css):
                    want = simulate_reference(g, m=m, alpha=float(a),
                                              compute_slots=cs)
                    assert grid[k, i, j, l] == want, (k, a, m, cs)


def test_empty_and_singleton_suites():
    alphas = [50.0, 200.0]
    empty = EDagSuite([])
    assert suite_sweep_grid(empty, alphas, ms=[2, 4]).shape == (0, 2, 2, 1)
    assert suite_t_inf_sweep(empty, alphas).shape == (0, 2)
    g = rand_edag(7, 35)
    single = EDagSuite([g])
    grid = suite_sweep_grid(single, alphas, ms=[2, 4], compute_slots=[0, 3])
    assert np.array_equal(grid[0], sweep_grid(g, alphas, ms=[2, 4],
                                              compute_slots=[0, 3]))
    # a suite whose only members are empty traces
    hollow = EDagSuite([EDag(), EDag()])
    assert np.array_equal(suite_sweep_grid(hollow, alphas),
                          np.zeros((2, 2, 1, 1)))


def test_suite_alphas_unsorted_and_duplicates_return_caller_order():
    suite = EDagSuite([rand_edag(3, 40), rand_edag(4, 20)])
    alphas = [200.0, 50.0, 200.0, 0.5, 50.0]
    grid = suite_sweep_grid(suite, alphas, ms=[2], compute_slots=[1])
    sweep = suite_latency_sweep(suite, alphas, m=2, compute_slots=1)
    for k, g in enumerate(suite.members):
        want = np.array([simulate_reference(g, m=2, alpha=a,
                                            compute_slots=1)
                         for a in alphas])
        assert np.array_equal(grid[k, :, 0, 0], want)
        assert np.array_equal(sweep[k], want)


def test_suite_degenerate_machine_models_keep_reference_semantics():
    suite = EDagSuite([rand_edag(5, 12), rand_edag(6, 8)])
    for alphas in ([0.0, 50.0], [-1.0, 2.0], [np.inf, 50.0]):
        grid = suite_sweep_grid(suite, alphas, ms=[2])
        for k, g in enumerate(suite.members):
            want = np.array([simulate_reference(g, m=2, alpha=float(a))
                             for a in alphas])
            assert np.array_equal(grid[k, :, 0, 0], want)


@pytest.mark.parametrize("backend", BACKENDS)
def test_suite_grid_exact_under_both_backends(backend):
    """The union replay stays bit-identical whichever kernel backend is
    requested (the float64 guard keeps non-x64 jax on the numpy kernel,
    so exactness is unconditional)."""
    suite = EDagSuite([rand_edag(11, 50), rand_edag(12, 30),
                       rand_edag(13, 1)])
    alphas = [0.5, 2.0, 50.0, 300.0]
    grid = suite_sweep_grid(suite, alphas, ms=[2, 4], compute_slots=[0, 2],
                            backend=backend)
    for k, g in enumerate(suite.members):
        assert np.array_equal(
            grid[k], sweep_grid(g, alphas, ms=[2, 4], compute_slots=[0, 2]))


def test_suite_memory_budget_invariant():
    """Streaming the union replay in minimum-size chunks changes no bits."""
    suite = EDagSuite([rand_edag(21, 45), rand_edag(22, 35)])
    alphas = np.linspace(40.0, 300.0, 14)
    full = suite_sweep_grid(suite, alphas, ms=[1, 4], compute_slots=[0, 3])
    tiny = suite_sweep_grid(suite, alphas, ms=[1, 4], compute_slots=[0, 3],
                            mem_budget=1)
    assert np.array_equal(full, tiny)


# --------------------------------------------------------- schedule reuse

def test_suite_cache_cold_then_warm(cache_env):
    """A cold suite records one schedule per (member, m, cs) and persists
    them keyed by each member's trace digest; a warm suite (fresh objects,
    same cache dir) records none and produces identical bits; a third run
    on the same object hits the union-plan memo."""
    alphas = [50.0, 100.0, 200.0]
    ms, css = [2, 4], [0, 2]
    seeds_sizes = [(0, 50), (1, 30), (2, 40)]

    suite1 = EDagSuite([rand_edag(s, n) for s, n in seeds_sizes])
    cold = suite_sweep_grid(suite1, alphas, ms=ms, compute_slots=css)
    assert sc.stats["record_runs"] == len(seeds_sizes) * len(ms) * len(css)
    assert sc.stats["stores"] == sc.stats["record_runs"]

    sc.reset_stats()
    suite2 = EDagSuite([rand_edag(s, n) for s, n in seeds_sizes])
    warm = suite_sweep_grid(suite2, alphas, ms=ms, compute_slots=css)
    assert sc.stats["record_runs"] == 0
    assert sc.stats["disk_hits"] == len(seeds_sizes) * len(ms) * len(css)
    assert np.array_equal(cold, warm)

    sc.reset_stats()
    memo = suite_sweep_grid(suite2, alphas, ms=ms, compute_slots=css)
    assert sc.stats["record_runs"] == 0 and sc.stats["disk_hits"] == 0
    assert np.array_equal(memo, warm)


def test_suite_reuses_single_trace_schedules_and_vice_versa(cache_env):
    """The suite path shares the member-digest-keyed entries with the
    single-trace engine in both directions."""
    alphas = [50.0, 100.0, 200.0]
    g = rand_edag(9, 60)
    latency_sweep(g, alphas, m=3, compute_slots=2)     # single-trace cold
    sc.reset_stats()
    suite = EDagSuite([rand_edag(9, 60), rand_edag(10, 20)])
    got = suite_sweep_grid(suite, alphas, ms=[3], compute_slots=[2])
    assert sc.stats["record_runs"] == 1                # only the new member
    assert np.array_equal(got[0, :, 0, 0],
                          latency_sweep(g, alphas, m=3, compute_slots=2))

    sc.reset_stats()
    fresh = rand_edag(10, 20)                          # suite warmed this one
    latency_sweep(fresh, alphas, m=3, compute_slots=2)
    assert sc.stats["record_runs"] == 0 and sc.stats["disk_hits"] == 1


def test_suite_warms_member_memo_below_disk_floor(monkeypatch):
    """With persistence disabled (or traces under the disk size floor),
    the member plan memo is the only reuse tier — a suite recording must
    still warm it, so later single-trace sweeps on the same member
    objects never re-pay the serial recording run."""
    monkeypatch.setenv("EDAN_SCHEDULE_CACHE", "off")
    sc.reset_stats()
    members = [rand_edag(61, 40), rand_edag(62, 25)]
    suite = EDagSuite(members)
    alphas = [50.0, 100.0, 200.0]
    grid = suite_sweep_grid(suite, alphas, ms=[2, 4], compute_slots=[1])
    assert sc.stats["record_runs"] == 2 * 2
    sc.reset_stats()
    for k, g in enumerate(members):
        for j, m in enumerate([2, 4]):
            got = latency_sweep(g, alphas, m=m, compute_slots=1)
            assert np.array_equal(got, grid[k, :, j, 0])
    assert sc.stats["record_runs"] == 0
    assert sc.stats["memory_hits"] == 2 * 2


def test_suite_use_cache_false_records_and_persists_nothing(cache_env):
    suite = EDagSuite([rand_edag(14, 30), rand_edag(15, 25)])
    alphas = [50.0, 200.0]
    got = suite_sweep_grid(suite, alphas, ms=[2], use_cache=False)
    assert sc.stats["record_runs"] == 2
    assert list(cache_env.glob("*.npz")) == []
    assert len(suite._suite_plans) == 0
    for k, g in enumerate(suite.members):
        assert np.array_equal(
            got[k, :, 0, 0],
            latency_sweep(g, alphas, m=2, use_cache=False))


def test_suite_tie_heavy_fallback_stays_exact(cache_env):
    """A memoized union plan recorded at a benign alpha must not certify
    tie-heavy points it cannot order — those fall back per member and the
    result stays bit-identical."""
    suite = EDagSuite([rand_edag(31, 70), rand_edag(32, 55)])
    suite_sweep_grid(suite, [50.0, 100.0, 200.0], ms=[2],
                     compute_slots=[1])
    tie_alphas = [0.5, 1.0, 2.0, 3.0]
    got = suite_sweep_grid(suite, tie_alphas, ms=[2], compute_slots=[1])
    for k, g in enumerate(suite.members):
        want = np.array([simulate_reference(g, m=2, alpha=a,
                                            compute_slots=1)
                         for a in tie_alphas])
        assert np.array_equal(got[k, :, 0, 0], want)


# ------------------------------------------------------------ analytic side

def test_suite_t_inf_sweep_matches_members():
    suite = EDagSuite([rand_edag(41, 40), rand_edag(42, 0),
                       rand_edag(43, 55)])
    alphas = np.linspace(10.0, 400.0, 23)
    got = suite_t_inf_sweep(suite, alphas)
    assert got.shape == (3, len(alphas))
    for k, g in enumerate(suite.members):
        assert np.array_equal(got[k], t_inf_sweep(g, alphas))


def test_suite_grid_report_matches_member_grid_reports():
    suite = EDagSuite([rand_edag(51, 45), rand_edag(52, 30)],
                      names=["left", "right"])
    alphas = [50.0, 125.0, 300.0]
    ms, css = [1, 2, 4], [0, 2]
    rep = suite_grid_report(suite, alphas, ms=ms, compute_slots=css,
                            simulate_points=True)
    assert rep["names"] == ["left", "right"]
    for k, g in enumerate(suite.members):
        r1 = grid_report(g, alphas, ms=ms, compute_slots=css,
                         simulate_points=True)
        assert rep["W"][k] == r1["W"] and rep["D"][k] == r1["D"]
        assert rep["C"][k] == r1["C"]
        assert np.array_equal(rep["lam"][k], r1["lam"])
        assert np.array_equal(rep["t_inf"][k], r1["t_inf"])
        assert np.array_equal(rep["t_lower"][k], r1["t_lower"])
        assert np.array_equal(rep["t_upper"][k], r1["t_upper"])
        assert np.array_equal(rep["Lam"][k], r1["Lam"])
        assert np.array_equal(rep["simulated"][k], r1["simulated"])


def test_suite_class_vector_grid_matches_members_and_reference():
    """Class-vector (2-D alpha) grids through the suite entry points:
    every per-trace slice equals the single-trace class engine and the
    per-event class reference; members keep their own overlays."""
    from repro.core import simulate_reference_classes
    members = [rand_edag(61, 35), rand_edag(62, 20), rand_edag(63, 0)]
    for k, g in enumerate(members):
        rng = np.random.default_rng(100 + k)
        g.set_mem_classes(rng.integers(0, 2, size=g.n_vertices,
                                       dtype=np.int32))
    suite = EDagSuite(members)
    rows = np.array([[40.0, 300.0], [300.0, 300.0], [120.0, 60.0]])
    ms, css = [1, 3], [0, 2]
    got = suite_sweep_grid(suite, rows, ms=ms, compute_slots=css)
    assert got.shape == (3, len(rows), len(ms), len(css))
    for k, g in enumerate(suite.members):
        assert np.array_equal(
            got[k], sweep_grid(g, rows, ms=ms, compute_slots=css))
        for p, row in enumerate(rows):
            assert got[k, p, 1, 0] == simulate_reference_classes(
                g, row, m=3)
    tinf = suite_t_inf_sweep(suite, rows)
    assert tinf.shape == (3, len(rows))
    for k, g in enumerate(suite.members):
        assert np.array_equal(tinf[k], t_inf_sweep(g, rows))
    for g in members:
        g.set_mem_classes(None)


def test_suite_class_grid_honors_env_mem_budget(monkeypatch):
    """Class-vector suite grids go through the same union plan and
    ``$EDAN_REPLAY_MEM_BUDGET`` chunk accounting as scalar runs — the
    per-member silent fallback that used to skip budget accounting is
    gone.  A tiny budget must multiply replay dispatches (chunks of ~one
    point each) and change no bits."""
    from repro.core import backend as bk

    members = [rand_edag(71, 40), rand_edag(72, 30)]
    for k, g in enumerate(members):
        rng = np.random.default_rng(200 + k)
        g.set_mem_classes(rng.integers(0, 2, size=g.n_vertices,
                                       dtype=np.int32))
    suite = EDagSuite(members)
    rows = np.array([[40.0, 300.0], [120.0, 60.0],
                     [80.0, 200.0], [300.0, 45.0]])
    ms, css = [2, 4], [0]
    # prove the class grid really builds union plans (one per distinct
    # m), not a per-member loop
    import repro.core.suite as suite_mod
    built = []
    orig_build = suite_mod._build_suite_plan

    def spy(suite_, pairs, unit, a0, use_cache, member_idx=None,
            n_classes=None):
        built.append(n_classes)
        return orig_build(suite_, pairs, unit, a0, use_cache,
                          member_idx=member_idx, n_classes=n_classes)

    monkeypatch.setattr(suite_mod, "_build_suite_plan", spy)
    bk.reset_stats()
    full = suite_sweep_grid(suite, rows, ms=ms, compute_slots=css)
    full_chunks = bk.stats["chunks"]
    assert full_chunks > 0
    assert built and all(nc == 2 for nc in built)
    monkeypatch.setenv("EDAN_REPLAY_MEM_BUDGET", "1")
    bk.reset_stats()
    tiny = suite_sweep_grid(suite, rows, ms=ms, compute_slots=css)
    assert bk.stats["chunks"] > full_chunks
    assert np.array_equal(full, tiny)
    for g in members:
        g.set_mem_classes(None)


def test_suite_axis_latency_grid_matches_per_step():
    from repro.core import (AxisSensitivity, axis_latency_grid, lambda_abs,
                            suite_axis_latency_grid)

    def axes(m0, scale):
        return {
            "model": AxisSensitivity(
                axis="model", W=64 * scale, D=8, bytes=2.0 ** 30,
                lam=lambda_abs(64 * scale, 8, m0),
                lam_seconds=lambda_abs(64 * scale, 8, m0) * 1e-6),
            "pod": AxisSensitivity(
                axis="pod", W=16, D=4 * scale, bytes=2.0 ** 28,
                lam=lambda_abs(16, 4 * scale, m0),
                lam_seconds=lambda_abs(16, 4 * scale, m0) * 1e-5),
        }

    per_step = {"step_a": axes(4, 1), "step_b": axes(4, 2)}
    secs = {"step_a": 1e-3, "step_b": 2e-3}
    alphas = [1e-6, 5e-6, 10e-6]
    ms = [2, 4, 8]
    got = suite_axis_latency_grid(per_step, alphas, ms, secs)
    for step, pa in per_step.items():
        want = axis_latency_grid(pa, alphas, ms, secs[step])
        assert set(got[step]) == set(want)
        for axis in pa:
            for key in ("lam", "lam_seconds", "Lam"):
                assert np.array_equal(got[step][axis][key],
                                      want[axis][key]), (step, axis, key)
    assert suite_axis_latency_grid({}, alphas, ms, {}) == {}
    assert suite_axis_latency_grid({"s": {}}, alphas, ms,
                                   {"s": 1e-3}) == {"s": {}}


# --------------------------------------------- heterogeneous-suite chunking

def test_member_groups_partition_streams_big_blocks():
    """A member too big to fit a full-width replay chunk in the budget
    becomes its own replay group; small members stay batched together;
    every member lands in exactly one group."""
    from repro.core.plan import ExecPolicy
    from repro.core.suite import _member_groups

    members = [rand_edag(40, 20), rand_edag(41, 600, p_edge=0.02),
               rand_edag(42, 25), rand_edag(43, 30)]
    suite = EDagSuite(members)
    P, n_pairs = 8, 2
    # budget sized so only the 600-vertex member overflows cap_rows
    pol = ExecPolicy.resolve(mem_budget=24 * P * 300 * n_pairs)
    groups = _member_groups(suite, n_pairs, P, pol)
    assert [1] in groups
    flat = sorted(i for grp in groups for i in grp)
    assert flat == [0, 1, 2, 3]
    covered = [i for grp in groups for i in grp]
    assert len(covered) == len(set(covered))
    # a huge budget keeps the whole suite in one batched group
    assert _member_groups(suite, n_pairs, P,
                          ExecPolicy.resolve(mem_budget=1 << 40)) \
        == [[0, 1, 2, 3]]


def test_heterogeneous_suite_grid_bit_identical_under_grouping():
    """Per-block chunking is invisible in the results: one dominant
    member among small ones, swept under budgets that force (a) the
    grouped path and (b) the minimum chunk, equals the per-member
    single-trace grids bit-for-bit."""
    members = [rand_edag(50, 20), rand_edag(51, 400, p_edge=0.03),
               rand_edag(52, 15)]
    suite = EDagSuite(members)
    alphas = [50.0, 100.0, 150.0, 200.0, 300.0]
    ms, css = [2, 4], [0, 2]
    want = [sweep_grid(g, alphas, ms=ms, compute_slots=css)
            for g in members]
    for budget in (None, 24 * len(alphas) * 200 * len(ms) * len(css), 1):
        got = suite_sweep_grid(suite, alphas, ms=ms, compute_slots=css,
                               mem_budget=budget)
        for k in range(len(members)):
            assert np.array_equal(got[k], want[k]), (k, budget)


def test_heterogeneous_suite_grouping_on_jax_backend():
    """Grouped replay through the error-bounded f32 device path (clean
    and dirty alphas mixed) still equals the numpy f64 grids exactly."""
    if len(BACKENDS) < 2:
        pytest.skip("jax not available")
    import jax
    from repro.core import backend as bk

    members = [rand_edag(60, 18), rand_edag(61, 300, p_edge=0.03),
               rand_edag(62, 22)]
    suite = EDagSuite(members)
    alphas = [50.0, 0.1, 125.0, 1.0 / 3.0, 300.0]
    ms, css = [2, 4], [0, 2]
    budget = 24 * len(alphas) * 150 * len(ms) * len(css)
    want = suite_sweep_grid(suite, alphas, ms=ms, compute_slots=css,
                            backend="numpy", mem_budget=budget,
                            use_cache=False)
    was = bool(jax.config.jax_enable_x64)
    jax.config.update("jax_enable_x64", False)  # pin the f32 mode
    try:
        bk.reset_stats()
        got = suite_sweep_grid(suite, alphas, ms=ms, compute_slots=css,
                               backend="jax", mem_budget=budget,
                               use_cache=False)
    finally:
        jax.config.update("jax_enable_x64", was)
    assert np.array_equal(got, want)
    assert bk.stats["jax_chunks"] > 0           # device replay ran
    assert bk.stats["demoted_columns"] > 0      # dirty columns demoted
