"""Streaming chunked build vs the retained legacy reference, plus the
memory-mapped trace store.

The default streaming/chunked build path must be **bit-identical** to
the legacy Python-list build it replaced — same digest, same arrays,
same levels, same simulated makespans — under every append pattern:
scalar/bulk mixes, multi-chunk edge streams, out-of-order edge blocks
(the counting-sort merge fallback), pending-buffer flush boundaries and
incremental re-finalization.  ``trace_store`` roundtrips must hand back
the same graph through a read-only memory map.
"""
import json
import os

import numpy as np
import pytest
from hypothesis import given, strategies as st

from repro.core import (EDag, latency_sweep, load_edag, put_trace,
                        get_trace, save_edag)
from repro.core import graph as graph_mod
from repro.core import trace_store


_ALPHAS = [3.0, 50.0, 200.0]


def _random_stream(g: EDag, seed: int, n_ops: int, p_block: float,
                   p_unsorted: float) -> None:
    """Append a deterministic random vertex/edge stream to ``g``.

    The same (seed, params) always produces the same stream, so applying
    it to a streaming and a legacy graph builds the same eDAG through
    two different storage disciplines.
    """
    rng = np.random.default_rng(seed)
    while g.n_vertices < 3:
        g.add_vertex(is_mem=bool(rng.random() < 0.5), nbytes=8.0)
    for _ in range(n_ops):
        r = rng.random()
        n = g.n_vertices
        if r < p_block:
            k = int(rng.integers(2, 12))
            if rng.random() < 0.5:        # per-vertex arrays + label list
                g.add_vertex_block(rng.random(k), rng.random(k) < 0.4,
                                   8.0 * rng.random(k),
                                   label=[f"l{i % 3}" for i in range(k)])
            else:                         # broadcast scalars, one label
                g.add_vertex_block(1.0, bool(rng.random() < 0.5), 8.0,
                                   label="blk", n=k)
            base = n
            n = g.n_vertices
            dst = rng.integers(base, n, size=min(2 * k, n - 1))
            src = (rng.random(len(dst)) * dst).astype(np.int64)
            if rng.random() < p_unsorted:
                # deliberately interleave dst ranges across blocks so
                # consecutive chunks overlap and collect() must fall
                # back to the global stable argsort
                dst = dst[::-1].copy()
                src = src[::-1].copy()
                order = np.argsort(src, kind="stable")
                src, dst = src[order], dst[order]
            g.add_edge_block(src, dst)
        else:
            v = g.add_vertex(cost=float(rng.random()),
                             is_mem=bool(rng.random() < 0.5),
                             nbytes=float(rng.integers(0, 64)),
                             label=f"v{int(rng.integers(0, 4))}")
            for _ in range(int(rng.integers(0, 3))):
                g.add_edge(int(rng.integers(0, v)), v)


def _assert_bit_identical(gs: EDag, gl: EDag) -> None:
    gs._finalize()
    gl._finalize()
    assert gs.trace_digest() == gl.trace_digest()
    assert np.array_equal(gs.src, gl.src)
    assert np.array_equal(gs.dst, gl.dst)
    assert np.array_equal(gs.level, gl.level)
    assert np.array_equal(gs.cost, gl.cost)
    assert np.array_equal(gs.is_mem, gl.is_mem)
    assert np.array_equal(gs.nbytes, gl.nbytes)
    assert list(gs.labels()) == list(gl.labels())
    assert np.array_equal(latency_sweep(gs, _ALPHAS, use_cache=False),
                          latency_sweep(gl, _ALPHAS, use_cache=False))


@given(st.integers(0, 2 ** 31), st.integers(4, 40), st.floats(0.1, 0.9))
def test_streaming_equals_legacy(seed, n_ops, p_block):
    gs = EDag()
    gl = EDag(legacy_build=True)
    assert not gs._legacy and gl._legacy
    for g in (gs, gl):
        _random_stream(g, seed, n_ops, p_block, p_unsorted=0.0)
    _assert_bit_identical(gs, gl)


@given(st.integers(0, 2 ** 31), st.integers(4, 30))
def test_unsorted_chunks_equal_legacy(seed, n_ops):
    """Overlapping per-chunk dst ranges defeat the counting-sort merge
    precondition; the global-argsort fallback must still be exact."""
    gs = EDag()
    gl = EDag(legacy_build=True)
    for g in (gs, gl):
        _random_stream(g, seed, n_ops, p_block=0.8, p_unsorted=0.9)
    _assert_bit_identical(gs, gl)


@given(st.integers(0, 2 ** 31), st.integers(3, 20), st.integers(3, 20))
def test_incremental_refinalize_equals_oneshot(seed, ops_a, ops_b):
    """finalize -> append more -> re-finalize must equal the one-shot
    build of the whole stream (the collapsed-chunk merge path)."""
    gs = EDag()
    gl = EDag(legacy_build=True)
    for g in (gs, gl):
        _random_stream(g, seed, ops_a, p_block=0.5, p_unsorted=0.2)
    gs._finalize()                    # collapse to one sorted chunk
    mid_digest = gs.trace_digest()
    for g in (gs, gl):
        _random_stream(g, seed + 1, ops_b, p_block=0.5, p_unsorted=0.2)
    assert gs.trace_digest() != mid_digest or gl.n_edges == gs.n_edges
    _assert_bit_identical(gs, gl)


def test_pending_buffer_flush_boundary(monkeypatch):
    """Scalar appends crossing the pending-buffer flush threshold land in
    numpy chunks without losing or duplicating elements."""
    monkeypatch.setattr(graph_mod, "_CHUNK_FLUSH", 7)
    gs = EDag()
    gl = EDag(legacy_build=True)
    for g in (gs, gl):
        for i in range(40):           # crosses the patched boundary often
            g.add_vertex(is_mem=(i % 3 == 0), nbytes=float(i))
            if i:
                g.add_edge(i - 1, i)
        g.add_edge_block([0, 1], [5, 7])
    assert gs.n_vertices == 40 and gs.n_edges == 41
    _assert_bit_identical(gs, gl)


def test_legacy_env_knob(monkeypatch):
    monkeypatch.setenv("EDAN_LEGACY_BUILD", "1")
    assert EDag()._legacy
    monkeypatch.setenv("EDAN_LEGACY_BUILD", "0")
    assert not EDag()._legacy
    monkeypatch.delenv("EDAN_LEGACY_BUILD")
    assert not EDag()._legacy
    assert EDag(legacy_build=True)._legacy


def test_traced_app_identical_under_both_builds(monkeypatch):
    from repro.apps import polybench

    g = polybench.trace_kernel("gemm", 6)
    monkeypatch.setenv("EDAN_LEGACY_BUILD", "1")
    gl = polybench.trace_kernel("gemm", 6)
    assert gl._legacy and not g._legacy
    _assert_bit_identical(g, gl)


# ------------------------------------------------------------- trace store

def _traced(seed: int = 0, n: int = 50) -> EDag:
    g = EDag()
    rng = np.random.default_rng(seed)
    for i in range(n):
        g.add_vertex(cost=float(rng.random()),
                     is_mem=bool(rng.random() < 0.5), nbytes=8.0,
                     label=f"v{i % 4}")
        for j in range(max(0, i - 4), i):
            if rng.random() < 0.4:
                g.add_edge(j, i)
    g._finalize()
    return g


def _mmap_backed(a: np.ndarray) -> bool:
    while a is not None:
        if isinstance(a, np.memmap):
            return True
        a = getattr(a, "base", None)
    return False


def test_store_roundtrip_mmap(tmp_path):
    g = _traced()
    p = save_edag(g, tmp_path / "t")
    assert (p / "meta.json").exists()
    g2 = load_edag(p)
    assert g2.trace_digest() == g.trace_digest()
    assert np.array_equal(g2.src, g.src)
    assert np.array_equal(g2.level, g.level)
    assert np.array_equal(g2.cost, g.cost)
    assert _mmap_backed(np.asarray(g2.src))
    assert np.array_equal(latency_sweep(g2, _ALPHAS, use_cache=False),
                          latency_sweep(g, _ALPHAS, use_cache=False))
    # an adopted graph is immutable: the append API must refuse
    with pytest.raises(ValueError):
        g2.add_vertex()
    with pytest.raises(ValueError):
        g2.add_edge(0, 1)


def test_store_roundtrip_eager(tmp_path):
    g = _traced(seed=1)
    p = save_edag(g, tmp_path / "t")
    g2 = load_edag(p, mmap=False)
    assert not _mmap_backed(np.asarray(g2.src))
    assert g2.trace_digest() == g.trace_digest()
    assert np.array_equal(g2.dst, g.dst)


def test_store_missing_derived_recomputed(tmp_path):
    g = _traced(seed=2)
    p = save_edag(g, tmp_path / "t", include_derived=False)
    for name in trace_store._DERIVED:
        assert not (p / f"{name}.npy").exists()
    g2 = load_edag(p)
    assert np.array_equal(g2.level, g.level)
    assert np.array_equal(latency_sweep(g2, _ALPHAS, use_cache=False),
                          latency_sweep(g, _ALPHAS, use_cache=False))


def test_store_digest_verification_catches_corruption(tmp_path):
    g = _traced(seed=3)
    p = save_edag(g, tmp_path / "t")
    meta = json.loads((p / "meta.json").read_text())
    meta["digest"] = "0" * len(meta["digest"])
    (p / "meta.json").write_text(json.dumps(meta))
    with pytest.raises(ValueError, match="digest"):
        load_edag(p)
    g3 = load_edag(p, verify=False)   # explicit opt-out still loads
    assert np.array_equal(g3.src, g.src)


def test_put_get_trace_digest_addressed(tmp_path, monkeypatch):
    monkeypatch.setenv("EDAN_TRACE_STORE", str(tmp_path))
    g = _traced(seed=4)
    p = put_trace(g)
    assert p is not None and str(p).startswith(str(tmp_path))
    g2 = get_trace(g.trace_digest())
    assert g2 is not None
    assert g2.trace_digest() == g.trace_digest()
    assert get_trace("f" * 64) is None
    monkeypatch.setenv("EDAN_TRACE_STORE", "off")
    assert put_trace(g) is None and get_trace(g.trace_digest()) is None


def test_store_save_requires_no_prior_finalize(tmp_path):
    g = EDag()
    a = g.add_vertex(is_mem=True)
    b = g.add_vertex()
    g.add_edge(a, b)
    p = save_edag(g, tmp_path / "t")   # save finalizes internally
    g2 = load_edag(p)
    assert g2.n_vertices == 2 and g2.n_edges == 1
