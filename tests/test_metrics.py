"""Eq 3-7 metrics: lambda, Lambda, bandwidth, data movement."""
import numpy as np
import pytest

from repro.core import (EDag, bandwidth_utilization, cost_vector,
                        data_movement_over_time, report, CostModelParams)


def ladder(n_mem, width):
    """width independent chains of n_mem memory accesses each."""
    g = EDag()
    for w in range(width):
        prev = None
        for _ in range(n_mem):
            v = g.add_vertex(is_mem=True, nbytes=8.0)
            if prev is not None:
                g.add_edge(prev, v)
            prev = v
    return g


def test_bandwidth_utilization_formula():
    g = ladder(4, 3)            # T_inf = 4 * alpha; 12 accesses * 8B
    B = bandwidth_utilization(g, alpha=100.0, cycles_per_second=1e9)
    assert B == pytest.approx(12 * 8 / (4 * 100.0) * 1e9)


def test_cost_vector():
    g = ladder(2, 1)
    c = cost_vector(g, alpha=50.0, unit=1.0)
    assert (c == 50.0).all()


def test_data_movement_conservation():
    """Each memory vertex contributes its bytes to every phase it spans;
    with tau == alpha each vertex spans ~1-2 phases."""
    g = ladder(4, 2)
    t, U = data_movement_over_time(g, alpha=100.0, tau=100.0)
    assert U.max() > 0
    # first phase: both chains' first access in flight: 2 * 8 bytes
    assert U[0] == pytest.approx(16.0)


def test_data_movement_peak_matches_width():
    wide = ladder(1, 10)
    narrow = ladder(10, 1)
    _, Uw = data_movement_over_time(wide, alpha=100.0, tau=10.0)
    # tau=7 keeps phase boundaries off the exact handoff instants (the
    # paper's K is boundary-inclusive: at t=k*alpha two chained accesses
    # overlap, doubling the reading at aligned taus)
    _, Un = data_movement_over_time(narrow, alpha=100.0, tau=7.3)
    assert Uw.max() == pytest.approx(80.0)    # all 10 in flight together
    assert Un.max() == pytest.approx(8.0)     # serialized chain
    assert len(Un) > len(Uw)                  # chain takes 10x longer


def test_report_sensitive_vs_insensitive():
    """Fig 8: chained accesses (G1) are more latency sensitive than
    independent accesses (G2) at the same memory work."""
    g1 = ladder(3, 1)       # depth 3
    g2 = ladder(1, 3)       # depth 1
    p = CostModelParams(m=4)
    r1, r2 = report(g1, p), report(g2, p)
    assert r1.W == r2.W == 3
    assert r1.lam > r2.lam
    # with m=1 both collapse to W (paper's Fig 8 observation)
    p1 = CostModelParams(m=1)
    assert report(g1, p1).lam == report(g2, p1).lam == 3
