"""Fault-tolerant analysis service (serve.analysis).

The acceptance properties of the request engine, driven by deterministic
injected faults (serve.faults):

* batching is invisible in results — co-batched members are
  bit-identical to solo runs;
* a poisoned member never corrupts its neighbours: the union is torn
  down into solo re-runs, the poison is quarantined, the healthy
  members' results stay bit-identical;
* every injected transient recovers within the retry budget (with the
  demotion ladder reported honestly);
* a deadline-exceeded request fails alone, with a structured error.

Most tests pin a clean fault environment (the CI fault-injection job
forces ``$EDAN_FAULTS`` globally; these tests assert exact behaviours of
*specific* faults).  ``test_service_survives_ambient_faults`` is the one
that deliberately runs under whatever the environment forces.
"""
import json

import numpy as np
import pytest

from repro.core import EDag, Tracer
from repro.core.metrics import grid_report
from repro.core.placement import search_placement
from repro.core.scheduler import _REPLAY_BYTES_PER_CELL
from repro.serve import (AnalysisRequest, AnalysisService, faults,
                         default_deadline_s, default_max_retries)

try:
    import jax  # noqa: F401
    BACKENDS = ("numpy", "jax")
except Exception:  # pragma: no cover - jax ships in the CI image
    BACKENDS = ("numpy",)

ALPHAS = (60.0, 140.0)
GRID = dict(alphas=ALPHAS, ms=(2, 4), compute_slots=(0,))

# captured before the autouse fixture scrubs it: the spec the CI
# fault-injection matrix forces, replayed by the ambient smoke test
import os                                              # noqa: E402
AMBIENT_FAULTS = os.environ.get("EDAN_FAULTS", "")


@pytest.fixture(autouse=True)
def clean_env(monkeypatch, tmp_path):
    """Deterministic fault + cache environment for exact assertions."""
    monkeypatch.delenv("EDAN_FAULTS", raising=False)
    monkeypatch.setenv("EDAN_SCHEDULE_CACHE", str(tmp_path / "sched"))
    faults.reset()
    # the jax-float64 demotion rung flips the process-global x64 flag;
    # restore it so tests of the seed model stack (int32 cache indices)
    # are unaffected by ladder walks here
    x64_was = (bool(jax.config.jax_enable_x64)
               if "jax" in BACKENDS else None)
    yield
    faults.reset()
    if x64_was is not None:
        jax.config.update("jax_enable_x64", x64_was)


def rand_edag(seed: int, n: int = 40, p_edge: float = 0.12) -> EDag:
    rng = np.random.default_rng(seed)
    g = EDag()
    for i in range(n):
        g.add_vertex(is_mem=bool(rng.random() < 0.5))
        for j in range(i):
            if rng.random() < p_edge:
                g.add_edge(j, i)
    return g


def svc(**kw):
    kw.setdefault("start", False)
    kw.setdefault("backoff_s", 0.0)
    return AnalysisService(**kw)


def req(seed: int, **kw):
    for k, v in GRID.items():
        kw.setdefault(k, v)
    return AnalysisRequest(trace=rand_edag(seed), **kw)


def assert_reports_equal(a: dict, b: dict):
    for key in ("alphas", "ms", "compute_slots", "lam", "t_inf",
                "t_lower", "t_upper", "Lam", "simulated"):
        assert np.array_equal(np.asarray(a[key]), np.asarray(b[key])), key
    for key in ("W", "D", "C"):
        assert a[key] == b[key]


# ---------------------------------------------------------------- happy path

def test_single_request_matches_grid_report():
    g = rand_edag(0)
    (res,) = svc().process([AnalysisRequest(trace=g, **GRID)])
    assert res.ok and res.error is None and res.retries == 0
    assert res.batch_rids == (res.rid,)
    want = grid_report(rand_edag(0), list(ALPHAS), ms=GRID["ms"],
                       compute_slots=GRID["compute_slots"],
                       simulate_points=True)
    assert np.array_equal(res.report["simulated"], want["simulated"])
    assert np.array_equal(res.report["t_inf"], want["t_inf"])
    assert res.report["W"] == float(want["W"])


@pytest.mark.parametrize("backend", BACKENDS)
def test_batched_results_bit_identical_to_solo(backend):
    reqs = [req(s, backend=backend) for s in (0, 1, 2)]
    batched = svc().process(reqs)
    assert all(r.ok for r in batched)
    assert all(len(r.batch_rids) == 3 for r in batched)
    for s, got in zip((0, 1, 2), batched):
        (solo,) = svc().process([req(s, backend=backend)])
        assert solo.ok and solo.batch_rids == (solo.rid,)
        assert_reports_equal(got.report, solo.report)


def test_union_alpha_slicing():
    """Requests with different alpha sets still batch; each gets exactly
    its own alphas back, bit-identical to a solo run."""
    r0 = req(0, alphas=(60.0, 140.0))
    r1 = req(1, alphas=(100.0, 220.0))
    a, b = svc().process([r0, r1])
    assert a.ok and b.ok and len(a.batch_rids) == 2
    assert a.report["alphas"].tolist() == [60.0, 140.0]
    assert b.report["alphas"].tolist() == [100.0, 220.0]
    (sa,) = svc().process([req(0, alphas=(60.0, 140.0))])
    (sb,) = svc().process([req(1, alphas=(100.0, 220.0))])
    assert_reports_equal(a.report, sa.report)
    assert_reports_equal(b.report, sb.report)


def test_incompatible_grids_do_not_batch():
    r0 = req(0, ms=(2,))
    r1 = req(1, ms=(4,))
    a, b = svc().process([r0, r1])
    assert a.ok and b.ok
    assert a.batch_rids == (a.rid,) and b.batch_rids == (b.rid,)


def test_memory_budget_splits_batches_and_priority_packs_first():
    # budget fits exactly two 40-vertex graphs on this grid: their
    # stacked replay cells plus their trace footprints (packing charges
    # member CSRs too — union construction copies them)
    def trace_bytes(seed):
        g = rand_edag(seed)
        g._finalize()
        return sum(g.array_nbytes().values())

    n_pairs = len(GRID["ms"]) * len(GRID["compute_slots"])
    rows2 = 2 * 40 * n_pairs
    budget = (rows2 * len(ALPHAS) * _REPLAY_BYTES_PER_CELL
              + trace_bytes(1) + trace_bytes(2))
    reqs = [req(0, priority=0), req(1, priority=5), req(2, priority=5)]
    out = svc(mem_budget=budget).process(reqs)
    assert all(r.ok for r in out)
    lo, hi1, hi2 = out
    # the two priority-5 requests share the first batch; the priority-0
    # one spills into its own
    assert set(hi1.batch_rids) == {hi1.rid, hi2.rid}
    assert lo.batch_rids == (lo.rid,)


def test_kernel_traced_server_side():
    (res,) = svc().process([AnalysisRequest(kernel="atax", n=6, **GRID)])
    assert res.ok and res.report["name"] == "atax"
    with_trace = svc().process(
        [AnalysisRequest(kernel="cg", n=3, alphas=(100.0,))])
    assert with_trace[0].ok


def test_unknown_kernel_fails_with_choices():
    (res,) = svc().process(
        [AnalysisRequest(kernel="ataxx", n=6, alphas=(100.0,),
                         max_retries=0)])
    assert not res.ok and res.error["code"] == "load-error"
    assert "atax" in res.error["message"]


def test_request_validation():
    with pytest.raises(ValueError):
        AnalysisRequest(alphas=(100.0,))             # neither trace nor kernel
    with pytest.raises(ValueError):
        AnalysisRequest(trace=rand_edag(0), kernel="atax")
    with pytest.raises(ValueError):
        AnalysisRequest(kernel="atax", deadline_s=0.0)
    with pytest.raises(ValueError):
        AnalysisRequest(kernel="atax", max_retries=-1)


# ------------------------------------------------------- retries + demotion

def test_transient_load_fault_recovers():
    faults.install("load", "io", count=1)
    (res,) = svc().process([req(0)])
    assert res.ok and res.retries == 1


def test_transient_finalize_fault_recovers():
    faults.install("finalize", "backend", count=1)
    (res,) = svc().process([req(0)])
    assert res.ok and res.retries == 1


def test_transient_replay_fault_demotes_and_recovers():
    faults.install("replay", "backend", count=1)
    (res,) = svc().process([req(0)])
    assert res.ok and res.retries == 1
    assert res.policy["demotions"] == 1
    assert (res.policy["backend"], res.policy["replay_dtype"]) == \
        ("jax", "float64")
    # demoted result is still bit-identical to the clean solo run
    faults.reset()
    (clean,) = svc().process([req(0)])
    assert_reports_equal(res.report, clean.report)


def test_kernel_fault_degrades_inside_backend():
    """A fault inside the jax kernel itself (backend.fault_hook) is
    swallowed by the backend's own best-effort dispatch — the request
    succeeds without even spending a service-level retry, bit-identical
    to a clean run."""
    if len(BACKENDS) < 2:
        pytest.skip("jax not available")
    faults.install("kernel", "backend")
    (res,) = svc().process([req(0, backend="jax")])
    assert res.ok and res.policy["demotions"] == 0
    faults.reset()
    (clean,) = svc().process([req(0, backend="jax")])
    assert_reports_equal(res.report, clean.report)


def test_retry_budget_exhaustion_is_structured():
    faults.install("replay", "backend")          # hard fault, all rungs
    (res,) = svc().process([req(0, max_retries=1)])
    assert not res.ok
    e = res.error
    assert e["code"] == "replay-error" and e["stage"] == "replay"
    assert set(e) == {"code", "stage", "message", "retries"}
    assert res.retries >= 1


def test_transient_report_fault_recovers():
    faults.install("report", "io", count=1)
    (res,) = svc().process([req(0)])
    assert res.ok and res.retries == 1


# --------------------------------------------------------- poison isolation

@pytest.mark.parametrize("backend", BACKENDS)
def test_poisoned_member_never_corrupts_cobatched_results(backend):
    """THE acceptance property: one poisoned member in a union batch is
    isolated and quarantined; every healthy member's report is
    bit-identical to a clean solo run."""
    # clean solo references first
    refs = {}
    for s in (0, 1, 2):
        (r,) = svc().process([req(s, backend=backend)])
        assert r.ok
        refs[s] = r.report

    service = svc()
    # the union pass always fails; rid 1's solo re-run also fails
    faults.install("replay", "backend", min_batch=2)
    faults.install("replay", "backend", rid=1)
    out = service.process([req(s, backend=backend) for s in (0, 1, 2)])
    healthy0, poisoned, healthy2 = out
    assert healthy0.ok and healthy2.ok
    assert not poisoned.ok
    assert poisoned.error["code"] == "replay-error"
    # isolation: healthy members were re-run solo
    assert healthy0.batch_rids == (healthy0.rid,)
    assert healthy2.batch_rids == (healthy2.rid,)
    # bit-identity with the clean solo references
    assert_reports_equal(healthy0.report, refs[0])
    assert_reports_equal(healthy2.report, refs[2])

    # quarantine: the same trace fails fast on the same service, even
    # with all faults cleared, and costs no neighbour anything
    faults.reset()
    again = service.process([req(1, backend=backend),
                             req(2, backend=backend)])
    assert not again[0].ok and again[0].error["code"] == "quarantined"
    assert again[1].ok
    assert_reports_equal(again[1].report, refs[2])


def test_quarantine_is_per_service_not_global():
    faults.install("replay", "backend")
    service = svc()
    (bad,) = service.process([req(7, max_retries=0)])
    assert not bad.ok
    faults.reset()
    (fresh,) = svc().process([req(7)])       # a new service has no memory
    assert fresh.ok


# ------------------------------------------------------------------ deadline

def test_deadline_exceeded_fails_alone():
    faults.install("load", "latency", rid=0, delay=0.3)
    out = svc().process([
        req(0, deadline_s=0.05, max_retries=0),
        req(1, deadline_s=60.0),
    ])
    slow, fast = out
    assert not slow.ok
    assert slow.error["code"] == "deadline"
    assert slow.error["stage"] == "load"
    assert fast.ok
    (ref,) = svc().process([req(1)])
    assert_reports_equal(fast.report, ref.report)


def test_deadline_checked_between_retries():
    """Backoff must never outlive the deadline: a hard fault with a big
    retry budget still resolves as a deadline error, promptly."""
    import time
    faults.install("replay", "backend")
    t0 = time.monotonic()
    (res,) = svc(backoff_s=0.05).process(
        [req(0, deadline_s=0.2, max_retries=1000)])
    assert not res.ok and res.error["code"] == "deadline"
    assert time.monotonic() - t0 < 30.0


def test_env_defaults_applied_at_admission(monkeypatch):
    monkeypatch.setenv("EDAN_DEADLINE_S", "0.0001")
    faults.install("load", "latency", delay=0.05)
    (res,) = svc().process([req(0)])
    assert not res.ok and res.error["code"] == "deadline"
    monkeypatch.setenv("EDAN_DEADLINE_S", "60")
    monkeypatch.setenv("EDAN_MAX_RETRIES", "0")
    faults.reset()
    faults.install("replay", "backend", count=1)
    (res2,) = svc().process([req(0, backend="numpy")])
    # zero retries and a one-rung numpy ladder: the transient is fatal
    assert not res2.ok and res2.error["code"] == "replay-error"


# --------------------------------------------------------------- placement

def placement_trace(seed: int = 0, n_obj: int = 3, n_ops: int = 24):
    """A deterministic multi-object trace (same seed => same digest)."""
    rng = np.random.default_rng(seed)
    tr = Tracer()
    arrs = [tr.array(np.arange(8.0 * (i + 1)), f"obj{i}")
            for i in range(n_obj)]
    acc = tr.const(0.0)
    for _ in range(n_ops):
        a = arrs[rng.integers(n_obj)]
        acc = tr.alu("+", acc, a.load(int(rng.integers(len(a.arr)))))
        if rng.random() < 0.4:
            b = arrs[rng.integers(n_obj)]
            b.store(int(rng.integers(len(b.arr))), acc)
    return tr.g, tr.object_sizes()


def preq(seed: int = 0, **kw):
    g, sizes = placement_trace(seed)
    kw.setdefault("object_sizes", sizes)
    kw.setdefault("local_budget", sum(sizes.values()) // 2)
    return AnalysisRequest(trace=g, kind="placement", **kw)


def assert_placement_reports_equal(a: dict, b: dict):
    for key in ("method", "local", "makespan", "all_local", "all_remote",
                "budget"):
        assert a[key] == b[key], key
    for key in ("budgets", "curve"):
        assert np.array_equal(np.asarray(a[key]), np.asarray(b[key])), key


def test_placement_request_matches_direct_search():
    (res,) = svc().process([preq(0)])
    assert res.ok and res.error is None and res.retries == 0
    rep = res.report
    assert rep["kind"] == "placement"
    g, sizes = placement_trace(0)
    want = search_placement(g, 1.0, 200.0, sum(sizes.values()) // 2,
                            sizes=sizes, m=4, compute_slots=0)
    assert rep["method"] == want.method
    assert tuple(rep["local"]) == want.local
    assert rep["makespan"] == want.makespan
    assert rep["all_local"] == want.all_local
    assert rep["all_remote"] == want.all_remote
    assert np.array_equal(np.asarray(rep["budgets"]), want.budgets)
    assert np.array_equal(np.asarray(rep["curve"]), want.curve)
    assert set(rep["marginal"]) == set(want.marginal)


def test_placement_runs_solo_in_a_mixed_wave():
    """A placement request in a wave with grid requests never joins their
    union batch, and the grid members' results stay bit-identical."""
    refs = [svc().process([req(s)])[0].report for s in (0, 1)]
    out = svc().process([req(0), preq(3), req(1)])
    grid0, place, grid1 = out
    assert all(r.ok for r in out)
    assert place.batch_rids == (place.rid,)
    assert place.report["kind"] == "placement"
    assert_reports_equal(grid0.report, refs[0])
    assert_reports_equal(grid1.report, refs[1])
    # the two grid members still co-batched with each other
    assert len(grid0.batch_rids) == 2 and len(grid1.batch_rids) == 2


def test_transient_placement_fault_demotes_and_recovers():
    faults.install("placement", "backend", count=1)
    (res,) = svc().process([preq(0)])
    assert res.ok and res.retries == 1
    assert res.policy["demotions"] == 1
    assert (res.policy["backend"], res.policy["replay_dtype"]) == \
        ("jax", "float64")
    faults.reset()
    (clean,) = svc().process([preq(0)])
    assert clean.policy["demotions"] == 0
    assert_placement_reports_equal(res.report, clean.report)


def test_hard_placement_fault_structured_and_quarantined():
    faults.install("placement", "backend")       # hard: survives the ladder
    service = svc()
    (res,) = service.process([preq(7, max_retries=1)])
    assert not res.ok
    e = res.error
    assert e["code"] == "replay-error" and e["stage"] == "placement"
    assert set(e) == {"code", "stage", "message", "retries"}
    # quarantine: the same trace digest fails fast on this service...
    faults.reset()
    (again,) = service.process([preq(7)])
    assert not again.ok and again.error["code"] == "quarantined"
    # ...but a fresh service has no memory of it
    (fresh,) = svc().process([preq(7)])
    assert fresh.ok


def test_placement_deadline_checked_between_retries():
    import time
    faults.install("placement", "backend")
    t0 = time.monotonic()
    (res,) = svc(backoff_s=0.05).process(
        [preq(0, deadline_s=0.2, max_retries=1000)])
    assert not res.ok
    assert res.error["code"] == "deadline"
    assert res.error["stage"] == "placement"
    assert time.monotonic() - t0 < 30.0


def test_placement_request_validation():
    g, _ = placement_trace(0)
    with pytest.raises(ValueError, match="local_budget"):
        AnalysisRequest(trace=g, kind="placement")
    with pytest.raises(ValueError, match="placement_method"):
        AnalysisRequest(trace=g, kind="placement", local_budget=0,
                        placement_method="magic")
    with pytest.raises(ValueError, match="kind"):
        AnalysisRequest(trace=g, kind="disaggregate")


def test_placement_result_persisted_as_valid_json(tmp_path):
    out_dir = tmp_path / "results"
    (res,) = svc(results_dir=out_dir).process([preq(0)])
    assert res.ok and res.stored is True
    (f,) = sorted(out_dir.glob("result_*.json"))
    doc = json.loads(f.read_text())
    assert doc["rid"] == res.rid
    assert doc["report"]["kind"] == "placement"
    assert doc["report"]["makespan"] == res.report["makespan"]
    assert doc["report"]["curve"] == \
        np.asarray(res.report["curve"]).tolist()


# ------------------------------------------------------- model-zoo requests

def mreq(config="qwen3-0.6b", phase="decode", **kw):
    for k, v in GRID.items():
        kw.setdefault(k, v)
    return AnalysisRequest(config=config, phase=phase, kind="model", **kw)


def test_model_request_matches_direct_grid_report():
    """kind='model' server-traces the config and the grid is bit-identical
    to tracing + grid_report by hand."""
    from repro.models.tracing import trace_model
    (res,) = svc().process([mreq()])
    assert res.ok and res.error is None
    assert res.report["name"] == "qwen3-0.6b:decode"
    g = trace_model("qwen3-0.6b", "decode", use_store=False)
    want = grid_report(g, list(ALPHAS), ms=GRID["ms"],
                       compute_slots=GRID["compute_slots"],
                       simulate_points=True)
    assert res.report["W"] == float(want["W"])
    assert res.report["D"] == float(want["D"])
    assert np.array_equal(res.report["simulated"], want["simulated"])
    assert np.array_equal(res.report["t_inf"], want["t_inf"])


def test_model_requests_join_union_batches():
    """Model requests are ordinary grid members: two configs plus an
    uploaded trace co-batch into one union, every result bit-identical
    to its solo run."""
    reqs = [mreq("qwen3-0.6b"), mreq("rwkv6-7b"), req(0)]
    batched = svc().process(reqs)
    assert all(r.ok for r in batched)
    assert all(len(r.batch_rids) == 3 for r in batched)
    for r, solo_req in zip(batched, [mreq("qwen3-0.6b"), mreq("rwkv6-7b"),
                                     req(0)]):
        (solo,) = svc().process([solo_req])
        assert_reports_equal(r.report, solo.report)


def test_transient_trace_model_fault_recovers():
    faults.install("trace-model", "io", count=1)
    (res,) = svc().process([mreq()])
    assert res.ok and res.retries == 1


def test_hard_trace_model_fault_structured():
    faults.install("trace-model", "io")          # hard fault, every attempt
    (res,) = svc().process([mreq(max_retries=1)])
    assert not res.ok
    assert res.error["code"] == "load-error"
    assert res.error["stage"] == "trace-model"
    assert res.retries >= 1


def test_unknown_config_fails_with_choices():
    (res,) = svc().process([mreq("not-a-model", max_retries=0)])
    assert not res.ok and res.error["code"] == "load-error"
    assert "qwen3-0.6b" in res.error["message"]


def test_model_request_validation():
    with pytest.raises(ValueError, match="phase"):
        AnalysisRequest(config="qwen3-0.6b", kind="model", phase="serve")
    with pytest.raises(ValueError, match="kind='model'"):
        AnalysisRequest(config="qwen3-0.6b")
    with pytest.raises(ValueError, match="exactly one"):
        AnalysisRequest(config="qwen3-0.6b", kernel="atax", kind="model")
    with pytest.raises(ValueError, match="config="):
        AnalysisRequest(kind="model")


# ------------------------------------------------------ background admission

def test_background_submit_and_run():
    service = AnalysisService(batch_window_s=0.01, backoff_s=0.0)
    try:
        out = service.run([req(0), req(1)], timeout=120.0)
        assert all(r.ok for r in out)
        assert out[0].rid != out[1].rid
    finally:
        service.close()
    with pytest.raises(RuntimeError):
        service.submit(req(2))


def test_close_drains_pending():
    service = AnalysisService(batch_window_s=0.05, backoff_s=0.0)
    tickets = [service.submit(req(s)) for s in (0, 1)]
    service.close()
    for t in tickets:
        assert t.event.wait(60.0)
        assert t.result is not None and t.result.ok


# ------------------------------------------------------------- result store

def test_results_persisted_as_valid_json(tmp_path):
    out_dir = tmp_path / "results"
    service = svc(results_dir=out_dir)
    (res,) = service.process([req(0)])
    assert res.ok and res.stored is True
    (f,) = sorted(out_dir.glob("result_*.json"))
    doc = json.loads(f.read_text())
    assert doc["rid"] == res.rid
    assert doc["report"]["simulated"] == \
        np.asarray(res.report["simulated"]).tolist()


def test_store_failure_degrades_not_fails(tmp_path):
    faults.install("store", "io")                # hard store fault
    service = svc(results_dir=tmp_path / "results")
    (res,) = service.process([req(0)])
    assert res.ok and res.stored is False        # degraded, not failed
    assert res.report is not None
    assert list((tmp_path / "results").glob("*.json")) == []


# ------------------------------------------- ambient (CI-forced) fault smoke

def test_service_survives_ambient_faults(monkeypatch):
    """Runs under whatever ``$EDAN_FAULTS`` the CI fault-injection
    matrix forces — every transient class must recover within the
    default budgets."""
    if AMBIENT_FAULTS:
        monkeypatch.setenv("EDAN_FAULTS", AMBIENT_FAULTS)
    faults.reset()                                # re-arm from the env
    try:
        service = AnalysisService(start=False, backoff_s=0.001)
        out = service.process([req(s, deadline_s=300.0)
                               for s in (0, 1, 2)])
        assert all(r.ok for r in out), [r.error for r in out]
        for s in (0, 1):                 # enough waves to reach every=K
            (solo,) = service.process([req(s, deadline_s=300.0)])
            assert solo.ok, solo.error
        for s in (0, 1):                 # the placement stage, too
            (place,) = service.process([preq(s, deadline_s=300.0)])
            assert place.ok, place.error
        # the trace-model stage, too: enough requests to reach every=K
        for ph in ("prefill", "decode", "decode"):
            (mdl,) = service.process([mreq(phase=ph, deadline_s=300.0)])
            assert mdl.ok, mdl.error
        if AMBIENT_FAULTS:
            assert sum(faults.fire_log.values()) > 0   # it really fired
    finally:
        faults.reset()


def test_crash_mid_result_write_leaves_nothing_or_valid(tmp_path):
    """SIGKILL while a result JSON is being persisted: a survivor sees
    either no result file or a complete parseable one — never a torn
    write (tempfile + os.replace, same recipe as the schedule cache)."""
    import os
    import signal
    import subprocess
    import sys

    out_dir = tmp_path / "results"
    src = os.path.join(os.path.dirname(os.path.dirname(
        os.path.abspath(__file__))), "src")
    child_code = (
        "import os, sys, time\n"
        f"sys.path.insert(0, {src!r})\n"
        "real_replace = os.replace\n"
        "def slow_replace(a, b):\n"
        "    print('REPLACING', flush=True)\n"
        "    time.sleep(30)\n"
        "    real_replace(a, b)\n"
        "import numpy as np\n"
        "from repro.core import EDag\n"
        "from repro.serve import AnalysisService, AnalysisRequest\n"
        "g = EDag()\n"
        "prev = None\n"
        "for i in range(12):\n"
        "    v = g.add_vertex(is_mem=(i % 2 == 0))\n"
        "    if prev is not None:\n"
        "        g.add_edge(prev, v)\n"
        "    prev = v\n"
        f"svc = AnalysisService(start=False, results_dir={str(out_dir)!r})\n"
        "os.replace = slow_replace\n"
        "svc.process([AnalysisRequest(trace=g, alphas=(100.0,))])\n")
    child = subprocess.Popen([sys.executable, "-c", child_code],
                             env=dict(os.environ),
                             stdout=subprocess.PIPE, text=True)
    line = child.stdout.readline().strip()
    assert line == "REPLACING", line
    os.kill(child.pid, signal.SIGKILL)
    child.wait(timeout=30)
    # no torn result: either nothing keyed, or valid JSON (here: nothing,
    # because the replace never ran — only tmp debris may remain)
    assert list(out_dir.glob("result_*.json")) == []
    for f in out_dir.glob("result_*.json"):
        json.loads(f.read_text())       # any keyed file must parse
    # a survivor service reuses the directory cleanly
    (res,) = svc(results_dir=out_dir).process([req(0)])
    assert res.ok and res.stored is True
    (kept,) = sorted(out_dir.glob("result_*.json"))
    json.loads(kept.read_text())
