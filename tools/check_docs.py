"""Docs checks for CI — offline, no extra dependencies.

1. **Link integrity**: every relative markdown link in ``README.md`` and
   ``docs/*.md`` must point at a file or directory that exists in the
   repo (anchors are stripped; ``http(s)``/``mailto`` links are skipped —
   CI runs offline).
2. **Executable quickstart**: every ```` ```python ```` fence in
   ``docs/SWEEPS.md``, ``docs/SERVICE.md``, ``docs/PERFORMANCE.md`` and
   ``docs/MODELS.md``
   is executed, top to bottom, in one shared namespace per document — the user guides' code
   is run on every CI push, so the documented API can never silently
   drift from the implementation.  Fences annotated
   ```` ```python no-run ```` are skipped (for illustrative fragments).

Usage: ``PYTHONPATH=src python tools/check_docs.py``
"""
from __future__ import annotations

import re
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
LINK_RE = re.compile(r"\[[^\]]*\]\(([^)\s]+)\)")
FENCE_RE = re.compile(r"^```python[ \t]*(no-run)?[ \t]*\n(.*?)^```",
                      re.MULTILINE | re.DOTALL)
# inline code spans and fenced blocks can contain example-link syntax
CODE_RE = re.compile(r"```.*?```|`[^`]*`", re.DOTALL)


def check_links(md: Path) -> list:
    errors = []
    text = CODE_RE.sub("", md.read_text())
    for target in LINK_RE.findall(text):
        if target.startswith(("http://", "https://", "mailto:", "#")):
            continue
        rel = target.split("#", 1)[0]
        if not rel:
            continue
        if not (md.parent / rel).exists() and not (ROOT / rel).exists():
            errors.append(f"{md.relative_to(ROOT)}: broken link -> "
                          f"{target}")
    return errors


def run_snippets(md: Path) -> list:
    """Execute the doc's python fences sequentially in one namespace."""
    for p in (str(ROOT), str(ROOT / "src")):
        if p not in sys.path:        # snippets import repro and benchmarks
            sys.path.insert(0, p)
    ns: dict = {"__name__": f"docs_snippet_{md.stem}"}
    errors = []
    for i, m in enumerate(FENCE_RE.finditer(md.read_text()), start=1):
        if m.group(1):                 # ```python no-run
            continue
        code = m.group(2)
        try:
            exec(compile(code, f"{md.name}#snippet{i}", "exec"), ns)
        except Exception as e:         # noqa: BLE001 - report and fail CI
            errors.append(f"{md.relative_to(ROOT)} snippet {i} raised "
                          f"{type(e).__name__}: {e}")
            break                      # later fences may depend on this one
    return errors


def main() -> int:
    errors = []
    docs = [ROOT / "README.md"] + sorted((ROOT / "docs").glob("*.md"))
    for md in docs:
        if md.exists():
            errors += check_links(md)
        else:
            errors.append(f"missing expected doc: {md.relative_to(ROOT)}")
    for doc in ("SWEEPS.md", "SERVICE.md", "PERFORMANCE.md", "MODELS.md"):
        errors += run_snippets(ROOT / "docs" / doc)
    for e in errors:
        print(f"FAIL {e}")
    if not errors:
        print(f"docs OK: {len(docs)} files link-checked, SWEEPS.md + "
              "SERVICE.md + PERFORMANCE.md + MODELS.md snippets executed")
    return 1 if errors else 0


if __name__ == "__main__":
    sys.exit(main())
