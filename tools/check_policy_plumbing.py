"""Static check: the execution-policy tuple must not be re-threaded.

``core/plan.py`` is the single home of the execution-policy tuple
(backend, replay_dtype, mem_budget, use_cache).  Public entry points
keep the historical keyword *signatures* as thin shims, but the only
call sites allowed to pass the raw policy kwargs onward are:

* anything inside ``core/plan.py`` itself, and
* calls to ``ExecPolicy.resolve(...)`` — the designated fold point every
  shim uses to turn its keywords into one frozen policy object.

Everything else must pass ``policy=`` / a resolved ``ExecPolicy``.  This
script walks every ``Call`` node under ``src/repro`` and fails (exit 1)
on any other call passing ``replay_dtype=``, ``mem_budget=`` or
``use_cache=`` as a keyword argument.  ``backend=`` is deliberately not
policed: the kernel layer (``core/backend.py``) legitimately dispatches
on it below the policy layer, and non-policy APIs use the name too.

Usage: ``PYTHONPATH=src python tools/check_policy_plumbing.py``
"""
from __future__ import annotations

import ast
import sys
from pathlib import Path

ROOT = Path(__file__).resolve().parent.parent
SRC = ROOT / "src" / "repro"

#: Kwargs that identify a raw execution-policy re-thread.
POLICY_KWARGS = {"replay_dtype", "mem_budget", "use_cache"}

#: Files where the raw tuple is the implementation, not a leak.
ALLOWED_FILES = {SRC / "core" / "plan.py"}


def _is_resolve_call(call: ast.Call) -> bool:
    """True for ``<anything>.resolve(...)`` — the shim fold point."""
    fn = call.func
    return isinstance(fn, ast.Attribute) and fn.attr == "resolve"


def check_file(path: Path) -> list:
    errors = []
    tree = ast.parse(path.read_text(), filename=str(path))
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call) or _is_resolve_call(node):
            continue
        bad = sorted(kw.arg for kw in node.keywords
                     if kw.arg in POLICY_KWARGS)
        if bad:
            errors.append(
                f"{path.relative_to(ROOT)}:{node.lineno}: call passes raw "
                f"policy kwarg(s) {', '.join(bad)} — resolve an ExecPolicy "
                f"once and pass policy= instead (see core/plan.py)")
    return errors


def main() -> int:
    errors = []
    for path in sorted(SRC.rglob("*.py")):
        if path in ALLOWED_FILES:
            continue
        errors.extend(check_file(path))
    if errors:
        print("\n".join(errors))
        print(f"\ncheck_policy_plumbing: {len(errors)} violation(s)")
        return 1
    print("check_policy_plumbing: OK (no raw policy kwarg re-threading "
          "outside core/plan.py)")
    return 0


if __name__ == "__main__":
    sys.exit(main())
