"""Regenerate the EXPERIMENTS.md roofline table from dry-run artifacts."""
import glob
import json
import os
import sys

ART = os.path.join(os.path.dirname(__file__), "artifacts")


def table(mesh_filter=None):
    rows = []
    for f in sorted(glob.glob(os.path.join(ART, "*.json"))):
        d = json.load(open(f))
        name = os.path.basename(f).replace(".json", "")
        if "skipped" in d:
            arch, shape, mesh = name.split("__")
            rows.append(f"| {arch} | {shape} | {mesh} | skip | — | — | — | — | — | — |")
            continue
        if "error" in d:
            continue
        if mesh_filter and d["mesh"] != mesh_filter:
            continue
        r = d["roofline"]
        lam = d.get("per_axis_lambda", {})
        lam_s = " ".join(f"{k.split('(')[0]}:{v['lam']:.0f}"
                         for k, v in sorted(lam.items())
                         if k in ("model", "data", "pod"))
        rows.append(
            f"| {d['arch']} | {d['shape']} | {d['mesh']} | "
            f"{'yes' if d['fits_hbm'] else 'NO'} "
            f"({d['hbm_per_device_bytes'] / 2**30:.1f}G) | "
            f"{r['compute_s']:.3g} | {r['memory_s']:.3g} | "
            f"{r['collective_s']:.3g} | **{r['dominant'][:4]}** | "
            f"{(d.get('useful_flops_ratio') or 0):.2f} | {lam_s} |")
    return rows


if __name__ == "__main__":
    hdr = ("| arch | shape | mesh | fits (HBM/dev) | compute s | memory s | "
           "collective s | dominant | useful | per-axis λ |")
    sep = "|" + "---|" * 10
    print(hdr)
    print(sep)
    for r in table():
        print(r)
